//! Distributed-transport property suite (`engine::transport` +
//! `engine::remote`): the wire format round-trips every `PreparedB`
//! variant bit-exactly (awkward floats included — NaN payloads, -0.0,
//! subnormals, infinities), and a sharded job routed over the socket
//! transport to real OS sockets is bit-identical to the in-process run
//! and the unsharded kernel for EVERY kernel in the default registry.
//! Fault injection: killing a socket worker mid-band resubmits only that
//! worker's lost bands to the survivor and still merges the bit-identical
//! result.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::remote::serve;
use spmm_accel::engine::transport::wire;
use spmm_accel::engine::{
    shard, Algorithm, CostHint, EngineError, EngineOutput, GustavsonKernel, PreparedB,
    Registry, RetryPolicy, ShardConfig, SocketTransport, SpmmKernel,
};
use spmm_accel::formats::csr::Csr;
use spmm_accel::formats::traits::FormatKind;
use spmm_accel::spmm::plan::Geometry;

/// Band alignment shared by the registry's blocked kernels and the shard
/// planner (same precondition as `prop_shard.rs`).
const BLOCK: usize = 16;

fn registry() -> Registry {
    Registry::with_default_kernels(Geometry { block: BLOCK, pairs: 32, slots: 16 }, 2)
}

/// Bind an ephemeral port, serve a shard worker on it forever (the thread
/// dies with the test process), and return its address.
fn spawn_worker(reg: Arc<Registry>) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind worker listener");
    let addr = listener.local_addr().expect("worker addr").to_string();
    std::thread::spawn(move || {
        let _ = serve(listener, reg);
    });
    addr
}

/// A retry policy with hedging effectively disabled, so fault-injection
/// counters measure exactly the loss-resubmission path.
fn no_hedge_policy() -> RetryPolicy {
    RetryPolicy {
        band_timeout: Duration::from_secs(30),
        retry_budget: 2,
        hedge_after: Duration::from_secs(600),
    }
}

// ---------------------------------------------------------------- wire

/// Every registered kernel's own `prepare` output survives the wire: the
/// decoded operand executes bit-identically to the original. This is the
/// real contract — `Pooled`/`Blocked` state is rebuilt host-local, so
/// byte-equality of the structs is neither required nor meaningful.
#[test]
fn every_kernels_prepared_operand_round_trips_the_wire_bit_exactly() {
    let reg = registry();
    let a = uniform(40, 48, 0.15, 101);
    let b = uniform(48, 36, 0.15, 102);
    let mut seen = Vec::new();
    for kernel in reg.kernels() {
        let prepared = kernel.prepare(&b).expect("prepare");
        seen.push(prepared.label());
        let mut w = wire::WireWriter::new();
        wire::put_prepared(&mut w, &prepared);
        let bytes = w.into_bytes();
        let mut r = wire::WireReader::new(&bytes);
        let decoded = wire::get_prepared(&mut r).expect("decode prepared");
        assert_eq!(r.remaining(), 0, "{}: trailing wire bytes", kernel.name());
        assert_eq!(decoded.label(), prepared.label(), "{}", kernel.name());
        let want = kernel.execute(&a, &prepared).expect("execute original");
        let got = kernel.execute(&a, &decoded).expect("execute decoded");
        assert_eq!(
            got.c.bit_pattern(),
            want.c.bit_pattern(),
            "{}: decoded operand executes differently",
            kernel.name()
        );
    }
    // the suite actually covered multiple distinct prepared representations
    seen.sort_unstable();
    seen.dedup();
    assert!(seen.len() >= 4, "only prepared variants {seen:?} exercised");
}

#[test]
fn awkward_float_bit_patterns_survive_the_wire() {
    // f32 payloads inside a CSR: NaN with payload, -0.0, subnormal, ±inf
    let vals = vec![
        f32::from_bits(0x7fc0_1234),
        -0.0f32,
        f32::from_bits(0x0000_0001),
        f32::INFINITY,
        f32::NEG_INFINITY,
    ];
    let m = Csr::from_parts(2, 5, vec![0, 3, 5], vec![0, 2, 4, 1, 3], vals.clone());
    let mut w = wire::WireWriter::new();
    wire::put_csr(&mut w, &m);
    let bytes = w.into_bytes();
    let mut r = wire::WireReader::new(&bytes);
    let back = wire::get_csr(&mut r).expect("csr with awkward floats");
    for (orig, got) in vals.iter().zip(&back.vals) {
        assert_eq!(orig.to_bits(), got.to_bits(), "f32 bit pattern changed");
    }
    // f64 bit patterns through the scalar path
    for bits in [
        0x7ff8_0000_0000_beefu64, // NaN with payload
        0x8000_0000_0000_0000,    // -0.0
        0x0000_0000_0000_0001,    // smallest subnormal
        0xfff0_0000_0000_0000,    // -inf
        0x3ff0_0000_0000_0001,    // 1.0 + 1ulp
    ] {
        let mut w = wire::WireWriter::new();
        w.put_f64_bits(f64::from_bits(bits));
        let bytes = w.into_bytes();
        let mut r = wire::WireReader::new(&bytes);
        let back = r.get_f64_bits().expect("f64");
        assert_eq!(back.to_bits(), bits, "f64 bit pattern changed");
    }
}

// -------------------------------------------------------------- sockets

/// The acceptance property: for every registered kernel, a sharded job
/// over real OS sockets (two workers) is bit-identical to the in-process
/// transport and to the unsharded kernel.
#[test]
fn socket_sharding_is_bit_identical_for_every_registered_kernel() {
    let peers = vec![
        spawn_worker(Arc::new(registry())),
        spawn_worker(Arc::new(registry())),
    ];
    let socket = SocketTransport::connect_with(&peers, no_hedge_policy()).expect("connect");
    let leader = registry();
    let cfg = ShardConfig { shards: 3, block: BLOCK };
    for (i, kernel) in leader.kernels().enumerate() {
        let seed = 200 + i as u64 * 7;
        let a = uniform(40 + i * 3, 48, 0.12, seed);
        let b = uniform(48, 36, 0.15, seed ^ 0x5A4D);
        let prepared = kernel.prepare(&b).expect("prepare");
        let unsharded = kernel.execute(&a, &prepared).expect("unsharded");
        let local = shard::execute(kernel.as_ref(), &a, Some(&b), &prepared, cfg)
            .expect("in-process sharded");
        let remote = shard::execute_with(&socket, kernel.as_ref(), &a, Some(&b), &prepared, cfg)
            .unwrap_or_else(|e| panic!("{}: socket run failed: {e}", kernel.name()));
        assert_eq!(
            remote.c.bit_pattern(),
            local.c.bit_pattern(),
            "{}: socket diverges from in-process",
            kernel.name()
        );
        assert_eq!(
            remote.c.bit_pattern(),
            unsharded.c.bit_pattern(),
            "{}: socket diverges from unsharded",
            kernel.name()
        );
        assert_eq!(
            remote.counters.remote_bands,
            remote.shards.len() as u64,
            "{}: every band must have executed remotely",
            kernel.name()
        );
        assert_eq!(remote.counters.workers_lost, 0, "{}", kernel.name());
        assert_eq!(local.counters.remote_bands, 0, "in-process is local by definition");
    }
}

/// Re-running with the same B must hit the remote staged cache instead of
/// re-shipping the operand (content-fingerprint keyed replication).
#[test]
fn repeated_jobs_reuse_the_remotely_staged_operand() {
    let peers = vec![spawn_worker(Arc::new(registry()))];
    let socket = SocketTransport::connect_with(&peers, no_hedge_policy()).expect("connect");
    let kernel = GustavsonKernel;
    let a = uniform(32, 40, 0.2, 301);
    let b = uniform(40, 24, 0.2, 302);
    let prepared = kernel.prepare(&b).expect("prepare");
    let cfg = ShardConfig { shards: 2, block: BLOCK };
    let first = shard::execute_with(&socket, &kernel, &a, Some(&b), &prepared, cfg).expect("first");
    assert!(first.counters.prepare_replications >= 1, "{:?}", first.counters);
    let second =
        shard::execute_with(&socket, &kernel, &a, Some(&b), &prepared, cfg).expect("second");
    assert_eq!(second.counters.prepare_replications, 0, "{:?}", second.counters);
    assert!(second.counters.prepare_reuse >= 1, "{:?}", second.counters);
    assert_eq!(first.c.bit_pattern(), second.c.bit_pattern());
}

// ------------------------------------------------------ fault injection

/// A kernel that dies mid-execute — installed on ONE worker's registry to
/// simulate a worker crash while bands are in flight (the handler thread
/// unwinds, the socket drops, the leader sees EOF).
struct PanicKernel;

impl SpmmKernel for PanicKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Gustavson
    }
    fn format(&self) -> FormatKind {
        FormatKind::Csr
    }
    fn name(&self) -> &'static str {
        "panic-on-execute"
    }
    fn cost_hint(&self, _a: &Csr, _b: &Csr) -> CostHint {
        CostHint { flops: 0.0, prepare_words: 0.0 }
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::Csr(Arc::new(b.clone())))
    }
    fn execute(&self, _a: &Csr, _b: &PreparedB) -> Result<EngineOutput, EngineError> {
        panic!("injected worker fault");
    }
}

/// Kill a socket worker mid-band: the leader must resubmit ONLY the lost
/// worker's outstanding bands to the survivor (not restart the job), count
/// exactly one lost worker, and still merge a result bit-identical to the
/// 1-shard local run.
#[test]
fn killing_a_worker_mid_band_resubmits_only_its_lost_bands() {
    let healthy = spawn_worker(Arc::new(registry()));
    let mut doomed_reg = registry();
    doomed_reg.register(Arc::new(PanicKernel));
    let doomed = spawn_worker(Arc::new(doomed_reg));
    let socket =
        SocketTransport::connect_with(&[healthy, doomed], no_hedge_policy()).expect("connect");

    let kernel = GustavsonKernel;
    let a = uniform(64, 48, 0.2, 401);
    let b = uniform(48, 40, 0.2, 402);
    let prepared = kernel.prepare(&b).expect("prepare");
    let want = shard::execute(&kernel, &a, Some(&b), &prepared, ShardConfig {
        shards: 1,
        block: BLOCK,
    })
    .expect("1-shard local");

    let cfg = ShardConfig { shards: 4, block: BLOCK };
    let out = shard::execute_with(&socket, &kernel, &a, Some(&b), &prepared, cfg)
        .expect("job must survive losing one worker");
    let bands = out.shards.len() as u64;
    assert_eq!(bands, 4, "planner should honor 4 bands on 64 rows");
    assert_eq!(
        out.c.bit_pattern(),
        want.c.bit_pattern(),
        "result after worker loss diverges from the 1-shard local run"
    );
    let c = out.counters;
    assert_eq!(c.workers_lost, 1, "{c:?}");
    assert!(
        c.band_retries >= 1 && c.band_retries < bands,
        "only the dead worker's bands may be resubmitted, not the whole job: {c:?}"
    );
    assert_eq!(c.hedges_won, 0, "hedging was disabled for this test: {c:?}");
    assert_eq!(c.remote_bands, bands, "every band still completed remotely: {c:?}");

    // the transport stays usable on the survivor afterwards
    let again = shard::execute_with(&socket, &kernel, &a, Some(&b), &prepared, cfg)
        .expect("survivor keeps serving");
    assert_eq!(again.c.bit_pattern(), want.c.bit_pattern());
    assert_eq!(again.counters.workers_lost, 0, "{:?}", again.counters);
}

/// With every worker dead the transport must fail typed — naming the
/// shards it could not place — rather than hang or panic.
#[test]
fn losing_every_worker_is_a_typed_error() {
    let mut doomed_reg = registry();
    doomed_reg.register(Arc::new(PanicKernel));
    let doomed = spawn_worker(Arc::new(doomed_reg));
    let socket = SocketTransport::connect_with(&[doomed], no_hedge_policy()).expect("connect");
    let kernel = GustavsonKernel;
    let a = uniform(32, 24, 0.3, 501);
    let b = uniform(24, 16, 0.3, 502);
    let prepared = kernel.prepare(&b).expect("prepare");
    let err = shard::execute_with(&socket, &kernel, &a, Some(&b), &prepared, ShardConfig {
        shards: 2,
        block: BLOCK,
    })
    .expect_err("no survivors should be a typed error");
    let msg = format!("{err}");
    assert!(
        msg.contains("shard") || msg.contains("worker"),
        "error should name the lost work: {msg}"
    );
}
