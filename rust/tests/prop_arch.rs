//! Property tests over the architecture simulators and the dispatch
//! planner: functional correctness of Algorithm 2, model agreement, and
//! exactly-once plan coverage.

use spmm_accel::arch::fpic::{simulate as fpic_simulate, Fidelity, FpicConfig};
use spmm_accel::arch::sync_mesh::{cycle_model, multiply_functional, SyncMeshConfig};
use spmm_accel::coordinator::split_batches;
use spmm_accel::datasets::synth::uniform;
use spmm_accel::formats::traits::SparseMatrix;
use spmm_accel::formats::Csr;
use spmm_accel::spmm::dense::multiply as dense_ref;
use spmm_accel::spmm::plan::{plan, Geometry};
use spmm_accel::util::ptest::check;
use spmm_accel::util::rng::Rng;

fn arb_pair(rng: &mut Rng) -> (Csr, Csr) {
    let m = 1 + rng.usize_below(30);
    let k = 1 + rng.usize_below(60);
    let n = 1 + rng.usize_below(25);
    let da = rng.f64() * 0.4;
    let db = rng.f64() * 0.4;
    (
        uniform(m, k, da, rng.next_u64()),
        uniform(k, n, db, rng.next_u64()),
    )
}

#[test]
fn prop_sync_mesh_computes_spmm() {
    check(0xA0, 20, arb_pair, |(a, b)| {
        let b_t = b.transpose();
        let mesh = 4;
        let (c, _) = multiply_functional(a, &b_t, SyncMeshConfig { mesh, round: 8 });
        let want = dense_ref(a, b);
        let diff = c.max_abs_diff(&want);
        if diff > 1e-3 {
            return Err(format!("max diff {diff}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cycle_model_matches_functional_sim() {
    check(0xA1, 15, arb_pair, |(a, b)| {
        let b_t = b.transpose();
        for (mesh, round) in [(2usize, 8usize), (4, 16), (8, 32)] {
            let cfg = SyncMeshConfig { mesh, round };
            let (_, f) = multiply_functional(a, &b_t, cfg);
            let m = cycle_model(a, &b_t, cfg);
            if f.cycles != m.cycles {
                return Err(format!(
                    "mesh {mesh} round {round}: functional {} != model {}",
                    f.cycles, m.cycles
                ));
            }
            if f.macs != m.macs {
                return Err(format!("macs {} != {}", f.macs, m.macs));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fpic_exact_computes_spmm() {
    check(0xA2, 15, arb_pair, |(a, b)| {
        let b_t = b.transpose();
        let (_, c) = fpic_simulate(
            a,
            &b_t,
            FpicConfig {
                units: 1,
                fidelity: Fidelity::Exact,
                ..FpicConfig::default()
            },
        );
        let diff = c.unwrap().max_abs_diff(&dense_ref(a, b));
        if diff > 1e-3 {
            return Err(format!("max diff {diff}"));
        }
        Ok(())
    });
}

#[test]
fn prop_plan_covers_every_block_pair_exactly_once() {
    check(0xA3, 25, arb_pair, |(a, b)| {
        let geom = Geometry { block: 8, pairs: 5, slots: 3 };
        let p = plan(a, b, geom);
        // real pairs across dispatches == total_pairs
        let counted: usize = p.dispatches.iter().map(|d| d.n_real).sum();
        if counted != p.total_pairs {
            return Err(format!("{counted} != {}", p.total_pairs));
        }
        // executing the plan on CPU equals the oracle (coverage + no dup)
        let got = p.execute_cpu();
        let want = dense_ref(a, b);
        let diff = got.max_abs_diff(&want);
        if diff > 1e-3 {
            return Err(format!("exec diff {diff}"));
        }
        // geometry invariants
        for d in &p.dispatches {
            if d.seg.len() != geom.pairs || d.slot_map.len() > geom.slots {
                return Err("dispatch geometry violated".into());
            }
            if d.seg.windows(2).any(|w| w[0] > w[1]) {
                return Err("segments not sorted".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_plan_insensitive_to_geometry() {
    // any (pairs, slots) chunking computes the same product
    check(0xA4, 15, arb_pair, |(a, b)| {
        let want = dense_ref(a, b);
        for (pairs, slots) in [(2usize, 1usize), (7, 2), (16, 16), (64, 4)] {
            let p = plan(a, b, Geometry { block: 16, pairs, slots });
            let got = p.execute_cpu();
            let diff = got.max_abs_diff(&want);
            if diff > 1e-3 {
                return Err(format!("P={pairs} T={slots}: diff {diff}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batches_partition_any_plan() {
    check(
        0xA5,
        200,
        |rng| (rng.usize_below(500), 1 + rng.usize_below(16)),
        |&(n, w)| {
            let b = split_batches(n, w);
            let total: usize = b.iter().map(|x| x.len()).sum();
            if total != n {
                return Err(format!("covered {total} of {n}"));
            }
            for pair in b.windows(2) {
                if pair[0].end != pair[1].start {
                    return Err("gap or overlap".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mesh_size_speedup_is_monotone_in_work() {
    // a bigger mesh never increases cycle count (same round size)
    check(0xA6, 10, arb_pair, |(a, b)| {
        let b_t = b.transpose();
        let mut prev = u64::MAX;
        for mesh in [2usize, 4, 8, 16] {
            let s = cycle_model(a, &b_t, SyncMeshConfig { mesh, round: 16 });
            // allow the fill-skew term to add mesh cycles for tiny inputs
            if s.cycles > prev.saturating_add(16 * 16) {
                return Err(format!("mesh {mesh}: {} > prev {prev}", s.cycles));
            }
            prev = s.cycles;
        }
        Ok(())
    });
}
