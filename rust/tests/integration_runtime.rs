//! Integration: the PJRT runtime executing the AOT-compiled Pallas kernels
//! against the CPU oracle — the proof that L1 (Pallas), L2 (JAX graph) and
//! L3 (Rust planner/runtime) compose.
//!
//! Requires `make artifacts` *and* `--features pjrt` (the vendored xla
//! bindings). Tests are skipped (not failed) when either is absent so
//! `cargo test` stays green pre-build / offline, but accelerator CI builds
//! artifacts and enables the feature first.

use spmm_accel::datasets::synth::uniform;
use spmm_accel::formats::dense::Dense;
use spmm_accel::formats::traits::SparseMatrix;
use spmm_accel::runtime::{Manifest, NumericEngine};
use spmm_accel::spmm::dense::multiply as dense_ref;

fn artifacts() -> Option<std::path::PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without --features pjrt");
        return None;
    }
    let dir = Manifest::default_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn pjrt_spmm_matches_oracle_across_densities() {
    let dir = require_artifacts!();
    let eng = NumericEngine::pjrt(&dir).expect("engine");
    for (density, seed) in [(0.01, 1u64), (0.05, 2), (0.2, 3)] {
        let a = uniform(100, 150, density, seed);
        let b = uniform(150, 90, density, seed + 10);
        let (c, report) = eng.spmm(&a, &b).expect("spmm");
        let want = dense_ref(&a, &b);
        let err = c.max_abs_diff(&want);
        assert!(err < 1e-3, "density {density}: err {err}");
        if a.nnz() > 0 && b.nnz() > 0 {
            assert!(report.dispatches >= 1);
        }
    }
}

#[test]
fn pjrt_and_cpu_backends_agree_exactly_in_structure() {
    let dir = require_artifacts!();
    let pjrt = NumericEngine::pjrt(&dir).expect("engine");
    let cpu = NumericEngine::cpu(pjrt.geometry());
    let a = uniform(64, 128, 0.08, 5);
    let b = uniform(128, 64, 0.08, 6);
    let (c1, r1) = pjrt.spmm(&a, &b).unwrap();
    let (c2, r2) = cpu.spmm(&a, &b).unwrap();
    assert_eq!(r1.dispatches, r2.dispatches);
    assert_eq!(r1.real_pairs, r2.real_pairs);
    assert!(c1.max_abs_diff(&c2) < 1e-4);
}

#[test]
fn pjrt_empty_and_tiny_jobs() {
    let dir = require_artifacts!();
    let eng = NumericEngine::pjrt(&dir).expect("engine");
    // structurally empty product
    let a = uniform(40, 40, 0.0, 1);
    let (c, report) = eng.spmm(&a, &a).unwrap();
    assert!(c.data.iter().all(|&v| v == 0.0));
    assert_eq!(report.dispatches, 0);
    // single-element matrices (padded up to one 32-block)
    let one = spmm_accel::formats::Csr::from_coo(&spmm_accel::formats::Coo::new(
        1,
        1,
        vec![(0, 0, 3.0)],
    ));
    let (c, _) = eng.spmm(&one, &one).unwrap();
    assert!((c.at(0, 0) - 9.0).abs() < 1e-5);
}

#[test]
fn dense_mm_artifact_matches_cpu() {
    let dir = require_artifacts!();
    let eng = NumericEngine::pjrt(&dir).expect("engine");
    let d = 256; // manifest dense_dim
    let mut rng = spmm_accel::util::rng::Rng::new(3);
    let x = Dense::new(d, d, (0..d * d).map(|_| rng.f32() - 0.5).collect());
    let y = Dense::new(d, d, (0..d * d).map(|_| rng.f32() - 0.5).collect());
    let got = eng.dense_mm(&x, &y).unwrap();
    let want = spmm_accel::spmm::dense::multiply_dense(&x, &y);
    // 256-term f32 dot products: allow accumulation-order slack
    assert!(got.max_abs_diff(&want) < 1e-2, "{}", got.max_abs_diff(&want));
}

#[test]
fn manifest_geometry_drives_the_planner() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.block, 32);
    assert_eq!(m.pairs, 128);
    assert_eq!(m.slots, 64);
    let eng = NumericEngine::pjrt(&dir).unwrap();
    assert_eq!(eng.geometry().block, m.block);
}

#[test]
fn rectangular_and_unaligned_shapes() {
    let dir = require_artifacts!();
    let eng = NumericEngine::pjrt(&dir).expect("engine");
    let a = uniform(33, 130, 0.1, 7);
    let b = uniform(130, 61, 0.1, 8);
    let (c, _) = eng.spmm(&a, &b).unwrap();
    assert_eq!(c.shape(), (33, 61));
    assert!(c.max_abs_diff(&dense_ref(&a, &b)) < 1e-3);
}
