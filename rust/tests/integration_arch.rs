//! Integration: the three architecture simulators against each other and
//! against the numeric oracle, at mesh scales beyond the unit tests.

use spmm_accel::arch::conventional::{cycles as conv_cycles, ConvMmConfig};
use spmm_accel::arch::fpic::{simulate as fpic_simulate, Fidelity, FpicConfig};
use spmm_accel::arch::sync_mesh::{cycle_model, multiply_functional, SyncMeshConfig};
use spmm_accel::datasets::spec::{ColumnDist, DatasetSpec, NnzRow};
use spmm_accel::datasets::synth::{generate, uniform};
use spmm_accel::formats::traits::SparseMatrix;
use spmm_accel::spmm::dense::multiply as dense_ref;

#[test]
fn functional_mesh_equals_oracle_at_16x16() {
    let a = uniform(40, 96, 0.15, 1);
    let b = uniform(96, 35, 0.12, 2);
    let b_t = b.transpose();
    let (c, stats) = multiply_functional(&a, &b_t, SyncMeshConfig { mesh: 16, round: 32 });
    let want = dense_ref(&a, &b);
    assert!(c.max_abs_diff(&want) < 1e-3, "{}", c.max_abs_diff(&want));
    // cycle model must agree exactly
    let m = cycle_model(&a, &b_t, SyncMeshConfig { mesh: 16, round: 32 });
    assert_eq!(stats.cycles, m.cycles);
    assert_eq!(stats.macs, m.macs);
}

#[test]
fn functional_mesh_handles_non_divisible_dims() {
    // ragged tiles: 13 rows, 11 cols on an 8x8 mesh
    let a = uniform(13, 50, 0.3, 3);
    let b = uniform(50, 11, 0.3, 4);
    let b_t = b.transpose();
    let (c, _) = multiply_functional(&a, &b_t, SyncMeshConfig { mesh: 8, round: 16 });
    assert!(c.max_abs_diff(&dense_ref(&a, &b)) < 1e-3);
}

#[test]
fn fpic_exact_equals_oracle_and_maxnode_tracks_it() {
    let a = uniform(48, 300, 0.06, 5);
    let (exact, c) = fpic_simulate(
        &a,
        &a,
        FpicConfig {
            units: 1,
            fidelity: Fidelity::Exact,
            ..FpicConfig::default()
        },
    );
    let a_t = a.transpose();
    let want = dense_ref(&a, &a_t);
    assert!(c.unwrap().max_abs_diff(&want) < 1e-3);
    let (fast, _) = fpic_simulate(&a, &a, FpicConfig::default());
    let rel = (exact.cycles as f64 - fast.cycles as f64).abs() / exact.cycles as f64;
    assert!(rel < 0.15, "exact {} vs fast {}", exact.cycles, fast.cycles);
}

#[test]
fn round_size_tradeoff_on_sync_mesh() {
    // paper §IV.B.b: larger R -> less synchronization (fewer, longer
    // rounds); with uniform data the cycle count is non-increasing in R
    let a = uniform(128, 512, 0.05, 6);
    let mut prev = u64::MAX;
    for r in [8usize, 16, 32, 64] {
        let s = cycle_model(&a, &a, SyncMeshConfig { mesh: 16, round: r });
        assert!(
            s.cycles <= prev + prev / 10,
            "R={r}: {} vs prev {prev}",
            s.cycles
        );
        prev = s.cycles;
    }
}

#[test]
fn fig5_shape_at_reduced_scale() {
    // one banded sparse + one dense dataset through all four designs
    let banded = DatasetSpec {
        name: "banded",
        rows: 2_000,
        cols: 2_000,
        stated_density: 0.002,
        nnz_row: NnzRow { min: 1, avg: 4.0, max: 16 },
        dist: ColumnDist::Banded(256),
    };
    let a_sparse = generate(&banded, 7);
    let a_dense = uniform(600, 2_000, 0.14, 8);

    for (name, a) in [("banded-sparse", &a_sparse), ("dense", &a_dense)] {
        let sync = cycle_model(a, a, SyncMeshConfig { mesh: 64, round: 32 });
        let (fp, _) = fpic_simulate(
            a,
            a,
            FpicConfig { units: 8, ..FpicConfig::default() },
        );
        let conv = conv_cycles(a.rows(), a.rows(), a.cols(), ConvMmConfig { mesh: 96 });
        // the headline: sync mesh is fastest on both ends of the density range
        assert!(
            fp.cycles > sync.cycles,
            "{name}: FPIC {} !> sync {}",
            fp.cycles,
            sync.cycles
        );
        assert!(
            conv.cycles > sync.cycles,
            "{name}: conv {} !> sync {}",
            conv.cycles,
            sync.cycles
        );
    }

    // crossover: conventional MM is *relatively* better on dense data
    let sync_d = cycle_model(&a_dense, &a_dense, SyncMeshConfig { mesh: 64, round: 32 });
    let conv_d = conv_cycles(600, 600, 2_000, ConvMmConfig { mesh: 96 });
    let sync_s = cycle_model(&a_sparse, &a_sparse, SyncMeshConfig { mesh: 64, round: 32 });
    let conv_s = conv_cycles(2_000, 2_000, 2_000, ConvMmConfig { mesh: 96 });
    let ratio_dense = conv_d.cycles as f64 / sync_d.cycles as f64;
    let ratio_sparse = conv_s.cycles as f64 / sync_s.cycles as f64;
    assert!(
        ratio_sparse > ratio_dense,
        "conv should fall behind on sparse: {ratio_sparse} !> {ratio_dense}"
    );
}

#[test]
fn utilization_accounting_is_consistent() {
    let a = uniform(64, 256, 0.1, 9);
    let s = cycle_model(&a, &a, SyncMeshConfig { mesh: 16, round: 32 });
    let macs_direct = spmm_accel::arch::useful_macs(&a, &a);
    assert_eq!(s.macs, macs_direct);
    let u = s.utilization(16);
    assert!(u > 0.0 && u < 1.0, "{u}");
}

#[test]
fn fpic_bandwidth_ablation_matters_on_heavy_rows() {
    // with 1400-nz rows the duplicate-fetch bound dominates merges
    let a = uniform(64, 10_000, 0.14, 10);
    let (with_bw, _) = fpic_simulate(&a, &a, FpicConfig::default());
    let (no_bw, _) = fpic_simulate(
        &a,
        &a,
        FpicConfig { model_bandwidth: false, ..FpicConfig::default() },
    );
    assert!(
        with_bw.cycles > 2 * no_bw.cycles,
        "bandwidth bound should dominate: {} vs {}",
        with_bw.cycles,
        no_bw.cycles
    );
    assert!(with_bw.fill_bound_tiles > 0);
}
