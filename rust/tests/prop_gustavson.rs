//! Bit-identity property suite for the vectorized, workspace-pooled
//! Gustavson backend (`spmm::gustavson_fast` + `GustavsonFastKernel`):
//!
//! 1. the fast algorithm body is bit-identical to the scalar
//!    `gustavson::multiply_counted` — structure, value bits, and MAC
//!    counts — on random inputs, reusing one workspace across cases;
//! 2. the kernel is bit-identical to the scalar `GustavsonKernel` at every
//!    worker count, through the registry key and through the sharded
//!    executor at {1, 2, 3, 5, 8} shards (the `prop_shard` property,
//!    asserted here for the new key explicitly);
//! 3. the symbolic pass sizes the numeric pass exactly (no `Vec` regrowth)
//!    and exact cancellation never double-emits a column;
//! 4. the workspace pool is shared by shard workers drawing on one
//!    `PreparedB`.

use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::{
    shard, Algorithm, GustavsonFastKernel, GustavsonKernel, PreparedB, Registry,
    ShardConfig, SpmmKernel,
};
use spmm_accel::formats::coo::Coo;
use spmm_accel::formats::csr::Csr;
use spmm_accel::formats::traits::{FormatKind, SparseMatrix};
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::spmm::{gustavson, gustavson_fast};
use spmm_accel::util::ptest::check;
use spmm_accel::util::rng::Rng;

const BLOCK: usize = 16;
const SHARD_COUNTS: [usize; 5] = [1, 2, 3, 5, 8];

fn gen_pair(rng: &mut Rng) -> (Csr, Csr) {
    let m = rng.usize_below(80) + 1;
    let k = rng.usize_below(60) + 1;
    let n = rng.usize_below(50) + 1;
    let da = rng.f64() * 0.35;
    let db = rng.f64() * 0.35;
    let seed = rng.next_u64();
    (uniform(m, k, da, seed), uniform(k, n, db, seed ^ 0xFA57))
}

fn same_csr_bits(x: &Csr, y: &Csr) -> Result<(), String> {
    if x.bit_pattern() != y.bit_pattern() {
        return Err(format!(
            "CSRs diverge bitwise: {:?}/{} nnz vs {:?}/{} nnz",
            x.shape(),
            x.nnz(),
            y.shape(),
            y.nnz()
        ));
    }
    Ok(())
}

/// 1. Algorithm body: fast == scalar bitwise, same MAC count, one reused
/// workspace across all cases (epoch stamping must isolate rows/jobs).
#[test]
fn prop_fast_body_is_bit_identical_to_scalar_gustavson() {
    let mut ws = gustavson_fast::Workspace::new(0);
    check(0x6057, 40, gen_pair, |(a, b)| {
        let (want, want_macs) = gustavson::multiply_counted(a, b);
        let (got, got_macs) = gustavson_fast::multiply_counted_ws(a, b, &mut ws);
        same_csr_bits(&want, &got)?;
        if want_macs != got_macs {
            return Err(format!("macs {want_macs} != {got_macs}"));
        }
        Ok(())
    });
}

/// 2a. Kernel vs kernel: every worker count renders the same Dense bits as
/// the scalar kernel.
#[test]
fn prop_fast_kernel_matches_scalar_kernel_at_every_worker_count() {
    check(0x6058, 12, gen_pair, |(a, b)| {
        let want = GustavsonKernel.run(a, b).map_err(|e| e.to_string())?.c.bit_pattern();
        for workers in [1usize, 2, 3, 7] {
            let out = GustavsonFastKernel::new(workers)
                .run(a, b)
                .map_err(|e| format!("{workers} workers: {e}"))?;
            if out.c.bit_pattern() != want {
                return Err(format!("{workers} workers diverge bitwise"));
            }
        }
        Ok(())
    });
}

/// 2b. Through the registry and the sharded executor: the new key resolves,
/// and sharded output at {1,2,3,5,8} is bit-identical to unsharded.
#[test]
fn fast_kernel_is_registered_and_shards_bit_identically() {
    let registry = Registry::with_default_kernels(
        Geometry { block: BLOCK, pairs: 32, slots: 16 },
        2,
    );
    let kernel = registry
        .resolve(FormatKind::Csr, Algorithm::GustavsonFast)
        .expect("(Csr, GustavsonFast) must be a default kernel");
    assert_eq!(kernel.name(), "gustavson-fast");
    let a = uniform(70, 90, 0.12, 1);
    let b = uniform(90, 40, 0.12, 2);
    let prepared = kernel.prepare(&b).unwrap();
    let want = kernel.execute(&a, &prepared).unwrap().c.bit_pattern();
    // also identical to the SCALAR kernel — the acceptance bar
    let scalar = GustavsonKernel.run(&a, &b).unwrap().c.bit_pattern();
    assert_eq!(want, scalar, "fast kernel diverges from scalar Gustavson");
    for shards in SHARD_COUNTS {
        let out = shard::execute(
            kernel.as_ref(),
            &a,
            Some(&b),
            &prepared,
            ShardConfig { shards, block: BLOCK },
        )
        .unwrap();
        assert_eq!(out.c.bit_pattern(), want, "{shards} shards diverge bitwise");
    }
}

/// 3. Symbolic sizing: structural counts bound the numeric output exactly
/// (equality without cancellation — `uniform` values are positive), and a
/// crafted cancellation shrinks the numeric row without re-emitting.
#[test]
fn prop_symbolic_pass_sizes_numeric_output() {
    let mut ws = gustavson_fast::Workspace::new(0);
    check(0x6059, 25, gen_pair, |(a, b)| {
        let band = gustavson_fast::multiply_band(a, 0, a.rows(), b, &mut ws);
        let counts = gustavson_fast::symbolic_row_nnz(a, 0, a.rows(), b, &mut ws);
        let total: usize = counts.iter().map(|&c| c as usize).sum();
        if band.symbolic_nnz != total {
            return Err(format!("symbolic {} != {}", band.symbolic_nnz, total));
        }
        // positive values: no cancellation, so sizing is exact per row
        for (i, &c) in counts.iter().enumerate() {
            let got = band.row_ptr[i + 1] - band.row_ptr[i];
            if got != c {
                return Err(format!("row {i}: sized {c}, emitted {got}"));
            }
        }
        Ok(())
    });
}

#[test]
fn cancellation_emits_once_in_both_scalar_and_fast() {
    // A row [1, -1, 2] × B rows [3], [3], [7]: column 0 cancels to exactly
    // 0.0 mid-row, then revives to 14 — the old scalar probe re-pushed the
    // column into its touched list here; both paths must emit it once
    let a = Csr::from_coo(&Coo::new(
        1,
        3,
        vec![(0, 0, 1.0), (0, 1, -1.0), (0, 2, 2.0)],
    ));
    let b = Csr::from_coo(&Coo::new(
        3,
        1,
        vec![(0, 0, 3.0), (1, 0, 3.0), (2, 0, 7.0)],
    ));
    let (scalar, _) = gustavson::multiply_counted(&a, &b);
    let fast = gustavson_fast::multiply(&a, &b);
    assert_eq!(scalar.nnz(), 1);
    assert_eq!(scalar.row(0), (&[0u32][..], &[14.0f32][..]));
    same_csr_bits(&scalar, &fast).unwrap();
    // full cancellation: the entry is dropped by both (nnz invariant)
    let b0 = Csr::from_coo(&Coo::new(3, 1, vec![(0, 0, 3.0), (1, 0, 3.0)]));
    let (scalar0, _) = gustavson::multiply_counted(&a, &b0);
    let fast0 = gustavson_fast::multiply(&a, &b0);
    assert_eq!(scalar0.nnz(), 0);
    same_csr_bits(&scalar0, &fast0).unwrap();
}

/// 4. One `PreparedB`, many shard workers: all of them draw from (and
/// return to) the same workspace pool.
#[test]
fn shard_workers_share_one_workspace_pool() {
    let kernel = GustavsonFastKernel::new(1);
    let a = uniform(96, 64, 0.15, 9);
    let b = uniform(64, 52, 0.15, 10);
    let prepared = kernel.prepare(&b).unwrap();
    let pool = match &prepared {
        PreparedB::Pooled(pb) => &pb.pool,
        other => panic!("unexpected prepared operand {other:?}"),
    };
    let out = shard::execute(
        &kernel,
        &a,
        Some(&b),
        &prepared,
        ShardConfig { shards: 4, block: BLOCK },
    )
    .unwrap();
    assert!(out.shards.len() > 1);
    let bands = out.shards.len() as u64;
    assert_eq!(pool.hits() + pool.misses(), bands, "one checkout per band");
    assert_eq!(pool.pooled() as u64, pool.misses(), "workspaces not returned");
    // the next sharded run draws on the parked workspaces; across both
    // runs the pool never allocates more than one workspace per concurrent
    // band, so at least half of all checkouts are reuses
    shard::execute(
        &kernel,
        &a,
        Some(&b),
        &prepared,
        ShardConfig { shards: 4, block: BLOCK },
    )
    .unwrap();
    assert_eq!(pool.hits() + pool.misses(), 2 * bands);
    assert!(pool.misses() <= bands, "allocated beyond peak concurrency");
    assert!(pool.hits() >= bands, "pool bypassed across sharded runs");
    assert_eq!(pool.pooled() as u64, pool.misses(), "workspaces not returned");
}

/// The wrapped-sharded registry path (`Registry::shard_all`) stays
/// bit-identical for the new kernel too.
#[test]
fn shard_all_wrapped_fast_kernel_is_bit_identical() {
    let a = uniform(50, 60, 0.2, 21);
    let b = uniform(60, 30, 0.2, 22);
    let mut reg = Registry::with_default_kernels(
        Geometry { block: BLOCK, pairs: 32, slots: 16 },
        2,
    );
    let inner = reg.resolve(FormatKind::Csr, Algorithm::GustavsonFast).unwrap();
    let want = inner.run(&a, &b).unwrap().c.bit_pattern();
    reg.shard_all(ShardConfig { shards: 3, block: BLOCK });
    let wrapped = reg.resolve(FormatKind::Csr, Algorithm::GustavsonFast).unwrap();
    assert_eq!(wrapped.name(), "sharded");
    assert_eq!(wrapped.run(&a, &b).unwrap().c.bit_pattern(), want);
}
