//! Property + acceptance suite for the outer-product SpGEMM backend:
//!
//! 1. `spmm::outer` is **bit-identical** to the scalar Gustavson oracle on
//!    random uniform inputs at every merge fan-in {1, 2, 3, 7} and worker
//!    count {1, 3}, with equal MAC counts;
//! 2. the same holds on hyper-sparse power-law (Zipf) inputs — the regime
//!    the backend exists for, with near-empty rows and skewed column
//!    degrees;
//! 3. the registered `(Csc, OuterProduct)` kernel matches the `(Csr,
//!    Gustavson)` kernel bitwise, unsharded and under `shard::execute` at
//!    shard counts {1, 2, 3, 5, 8};
//! 4. `Registry::shard_all` wraps the outer kernel and stays bit-identical;
//! 5. cancellation produces **exact zeros that are dropped**, matching the
//!    scalar kernel's `v != 0.0` emission filter;
//! 6. CSC, CSR, and COO submissions of the same content through a real
//!    coordinator server produce bit-identical output.

use std::sync::Arc;

use spmm_accel::coordinator::{Server, ServerConfig};
use spmm_accel::datasets::{generate, uniform, ColumnDist, DatasetSpec, NnzRow};
use spmm_accel::engine::{shard, Algorithm, Registry, ShardConfig, SpmmKernel};
use spmm_accel::formats::coo::Coo;
use spmm_accel::formats::csr::Csr;
use spmm_accel::formats::traits::{FormatKind, SparseMatrix};
use spmm_accel::formats::MatrixOperand;
use spmm_accel::spmm::gustavson;
use spmm_accel::spmm::outer::{self, MergePool, OuterConfig};
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::ptest::check;
use spmm_accel::util::rng::Rng;

const BLOCK: usize = 16;

fn registry() -> Registry {
    Registry::with_default_kernels(Geometry { block: BLOCK, pairs: 32, slots: 16 }, 2)
}

/// Hyper-sparse power-law matrix: Zipf column popularity, rows ranging
/// from empty to a handful of entries — the regime where row-centric
/// kernels waste their workspaces and the outer product pays off.
fn power_law(rows: usize, cols: usize, avg: f64, skew: f64, seed: u64) -> Csr {
    generate(
        &DatasetSpec {
            name: "prop-outer-zipf",
            rows,
            cols,
            stated_density: avg / cols as f64,
            nnz_row: NnzRow { min: 0, avg, max: rows.min(48) },
            dist: ColumnDist::Zipf(skew),
        },
        seed,
    )
}

/// Random compatible (A, B) pair mixing shapes and densities.
fn gen_pair(rng: &mut Rng) -> (Csr, Csr) {
    let m = rng.usize_below(40) + 4;
    let k = rng.usize_below(40) + 4;
    let n = rng.usize_below(40) + 4;
    let da = 0.03 + rng.f64() * 0.25;
    let db = 0.03 + rng.f64() * 0.25;
    let seed = rng.next_u64();
    (uniform(m, k, da, seed), uniform(k, n, db, seed ^ 0xC0DE))
}

/// 1. Outer == scalar Gustavson, bit for bit, at every fan-in and worker
/// count, with the same MAC count.
#[test]
fn prop_outer_matches_gustavson_bitwise_on_random_inputs() {
    check(0x007E4, 12, gen_pair, |(a, b)| {
        let (want, want_macs) = gustavson::multiply_counted(a, b);
        let want_bits = want.bit_pattern();
        for fan_in in [1usize, 2, 3, 7] {
            for workers in [1usize, 3] {
                let pool = MergePool::default();
                let (got, macs, _) =
                    outer::multiply_counted(a, b, &OuterConfig { fan_in, workers }, &pool);
                if got.bit_pattern() != want_bits {
                    return Err(format!(
                        "outer diverges bitwise at fan_in={fan_in} workers={workers}"
                    ));
                }
                if macs != want_macs {
                    return Err(format!(
                        "MAC count {macs} != Gustavson {want_macs} at \
                         fan_in={fan_in} workers={workers}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// 2. The same bit-identity on hyper-sparse power-law inputs.
#[test]
fn outer_matches_gustavson_on_power_law_inputs() {
    for (seed, skew, avg) in [(80u64, 1.1, 2.0), (81, 1.4, 4.0), (82, 0.9, 3.0)] {
        let a = power_law(96, 128, avg, skew, seed);
        let b = power_law(128, 80, avg, skew, seed ^ 0xBEEF);
        let (want, want_macs) = gustavson::multiply_counted(&a, &b);
        let want_bits = want.bit_pattern();
        for fan_in in [1usize, 2, 3, 7] {
            let pool = MergePool::default();
            let (got, macs, _) = outer::multiply_counted(
                &a,
                &b,
                &OuterConfig { fan_in, workers: 2 },
                &pool,
            );
            assert_eq!(
                got.bit_pattern(),
                want_bits,
                "power-law divergence at seed={seed} fan_in={fan_in}"
            );
            assert_eq!(macs, want_macs, "seed={seed} fan_in={fan_in}");
        }
    }
}

/// 3. The registered kernel matches the Gustavson kernel bitwise,
/// unsharded and at shard counts {1, 2, 3, 5, 8}.
#[test]
fn registered_outer_kernel_is_bit_identical_across_shard_counts() {
    let reg = registry();
    let outer_k = reg
        .resolve(FormatKind::Csc, Algorithm::OuterProduct)
        .expect("outer kernel registered");
    let gust = reg
        .resolve(FormatKind::Csr, Algorithm::Gustavson)
        .expect("gustavson kernel registered");
    let a = power_law(80, 96, 3.0, 1.2, 90);
    let b = power_law(96, 64, 3.0, 1.2, 91);
    let want = gust.run(&a, &b).unwrap().c.bit_pattern();
    let prepared = outer_k.prepare(&b).unwrap();
    assert_eq!(outer_k.execute(&a, &prepared).unwrap().c.bit_pattern(), want);
    for shards in [1usize, 2, 3, 5, 8] {
        let out = shard::execute(
            outer_k.as_ref(),
            &a,
            Some(&b),
            &prepared,
            ShardConfig { shards, block: BLOCK },
        )
        .unwrap();
        assert_eq!(
            out.c.bit_pattern(),
            want,
            "outer kernel diverges at {shards} shards"
        );
    }
}

/// 4. `shard_all` wraps the outer kernel; the wrapped kernel stays
/// bit-identical to the unwrapped run.
#[test]
fn shard_all_wraps_outer_bit_identically() {
    let mut reg = registry();
    let a = uniform(64, 80, 0.08, 92);
    let b = uniform(80, 56, 0.08, 93);
    let want = reg
        .resolve(FormatKind::Csc, Algorithm::OuterProduct)
        .unwrap()
        .run(&a, &b)
        .unwrap()
        .c
        .bit_pattern();
    reg.shard_all(ShardConfig { shards: 3, block: BLOCK });
    let wrapped = reg
        .resolve(FormatKind::Csc, Algorithm::OuterProduct)
        .expect("outer survives shard_all");
    assert_eq!(wrapped.name(), "sharded");
    assert_eq!(wrapped.run(&a, &b).unwrap().c.bit_pattern(), want);
}

/// 5. Cancellation produces an exact zero that is dropped from the sparse
/// result — exactly like the scalar kernel's `v != 0.0` filter.
#[test]
fn cancellation_drops_exact_zeros_like_gustavson() {
    // C[0,0] = 1*1 + (-1)*1 = exactly 0 -> dropped; C[0,1] = 0.5 survives
    let a = Csr::from_coo(&Coo::new(
        1,
        3,
        vec![(0, 0, 1.0), (0, 1, -1.0), (0, 2, 0.5)],
    ));
    let b = Csr::from_coo(&Coo::new(
        3,
        2,
        vec![(0, 0, 1.0), (1, 0, 1.0), (2, 1, 1.0)],
    ));
    let (want, _) = gustavson::multiply_counted(&a, &b);
    assert_eq!(want.nnz(), 1, "oracle must drop the cancelled cell");
    for fan_in in [1usize, 2, 7] {
        let pool = MergePool::default();
        let (got, _, _) =
            outer::multiply_counted(&a, &b, &OuterConfig { fan_in, workers: 1 }, &pool);
        assert_eq!(got.bit_pattern(), want.bit_pattern(), "fan_in={fan_in}");
    }
}

/// 6. CSC, CSR, and COO submissions of the same content through a real
/// server are bit-identical on the outer kernel.
#[test]
fn csc_csr_and_coo_ingestion_are_bit_identical_through_the_server() {
    let s = Server::start(ServerConfig {
        workers: 1,
        queue_depth: 4,
        geometry: Geometry { block: BLOCK, pairs: 32, slots: 16 },
        ..Default::default()
    });
    let client = s.client();
    let a = Arc::new(power_law(48, 64, 3.0, 1.2, 94));
    let b = Arc::new(power_law(64, 40, 3.0, 1.2, 95));
    let b_op = MatrixOperand::from(Arc::clone(&b));
    let run = |bo: MatrixOperand| {
        client
            .job(MatrixOperand::from(Arc::clone(&a)), bo)
            .kernel(FormatKind::Csc, Algorithm::OuterProduct)
            .submit()
            .unwrap()
            .wait()
            .unwrap()
    };
    let want = run(b_op.clone());
    for kind in [FormatKind::Csc, FormatKind::Coo] {
        let got = run(b_op.convert(kind).unwrap());
        assert_eq!(
            want.c.as_ref().unwrap().bit_pattern(),
            got.c.as_ref().unwrap().bit_pattern(),
            "{kind:?} submission diverges from CSR on the outer kernel"
        );
    }
    drop(client);
    s.shutdown();
}
