//! Property tests (custom `util::ptest` harness — proptest is unavailable
//! offline) over the format layer's invariants.

use spmm_accel::datasets::synth::uniform;
use spmm_accel::formats::convert::{from_coo, ALL_KINDS};
use spmm_accel::formats::incrs::{InCrs, InCrsParams};
use spmm_accel::formats::traits::{CountSink, SparseMatrix};
use spmm_accel::formats::{Coo, Csc, Csr};
use spmm_accel::util::ptest::check;
use spmm_accel::util::rng::Rng;

/// Random COO matrix with random shape/density.
fn arb_coo(rng: &mut Rng) -> Coo {
    let rows = 1 + rng.usize_below(40);
    let cols = 1 + rng.usize_below(600);
    let density = rng.f64() * 0.3;
    uniform(rows, cols, density, rng.next_u64()).to_coo()
}

#[test]
fn prop_every_format_roundtrips_coo() {
    check(0xF0, 40, arb_coo, |coo| {
        for kind in ALL_KINDS {
            let m = from_coo(kind, coo).map_err(|e| format!("{kind:?}: {e}"))?;
            if m.to_coo().entries != coo.entries {
                return Err(format!("{kind:?} round-trip mismatch"));
            }
            if m.nnz() != coo.nnz() {
                return Err(format!("{kind:?} nnz {} != {}", m.nnz(), coo.nnz()));
            }
        }
        Ok(())
    });
}

/// The Coo→CSR fast path: entries arriving already row-major skip the
/// construction sort; the resulting Coo and its CSR render must be
/// bit-identical to building from the same entries shuffled (the sorting
/// path).
#[test]
fn prop_coo_row_major_fast_path_is_bit_identical_to_the_sorting_path() {
    let gen = |rng: &mut Rng| {
        let coo = arb_coo(rng);
        let mut shuffled = coo.entries.clone();
        rng.shuffle(&mut shuffled);
        (coo, shuffled)
    };
    check(0xF7, 30, gen, |(coo, shuffled)| {
        let (rows, cols) = coo.shape();
        // coo.entries are sorted (Coo invariant): this construction takes
        // the fast path; the shuffled clone forces the sort
        let fast = Coo::new(rows, cols, coo.entries.clone());
        let slow = Coo::new(rows, cols, shuffled.clone());
        if fast.entries.len() != slow.entries.len() {
            return Err("entry counts diverge".into());
        }
        for (x, y) in fast.entries.iter().zip(&slow.entries) {
            if (x.0, x.1, x.2.to_bits()) != (y.0, y.1, y.2.to_bits()) {
                return Err(format!("entries diverge at ({}, {})", x.0, x.1));
            }
        }
        let csr_fast = Csr::from_coo(&fast);
        let csr_slow = Csr::from_coo(&slow);
        if csr_fast.bit_pattern() != csr_slow.bit_pattern() {
            return Err("CSR renders diverge bitwise".into());
        }
        Ok(())
    });
}

#[test]
fn prop_locate_agrees_across_all_formats() {
    check(0xF1, 25, arb_coo, |coo| {
        let mats: Vec<_> = ALL_KINDS
            .iter()
            .map(|&k| from_coo(k, coo).unwrap())
            .collect();
        let (rows, cols) = coo.shape();
        let mut rng = Rng::new(coo.nnz() as u64 + 1);
        for _ in 0..60 {
            let i = rng.usize_below(rows);
            let j = rng.usize_below(cols);
            let want = coo.get(i, j);
            for m in &mats {
                let got = m.get(i, j).filter(|&v| v != 0.0);
                if got != want {
                    return Err(format!(
                        "{:?} ({i},{j}): {got:?} != {want:?}",
                        m.kind()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_incrs_counters_are_prefix_sums() {
    check(0xF2, 40, arb_coo, |coo| {
        let csr = Csr::from_coo(coo);
        let params = InCrsParams { section: 64, block: 8 };
        let incrs = InCrs::from_csr_params(&csr, params).map_err(|e| e.to_string())?;
        let spr = (coo.shape().1 + 63) / 64;
        for i in 0..coo.shape().0 {
            let (cs, _) = csr.row(i);
            for s in 0..spr {
                let word = incrs.counters[i * spr + s];
                let prefix = (word & 0xFFFF) as usize;
                let want_prefix = cs.iter().filter(|&&c| (c as usize) < s * 64).count();
                if prefix != want_prefix {
                    return Err(format!(
                        "row {i} section {s}: prefix {prefix} != {want_prefix}"
                    ));
                }
                // block counts sum to the section population
                let bits = params.bits_per_block();
                let mask = (1u64 << bits) - 1;
                let in_section: u64 = (0..8)
                    .map(|b| (word >> (16 + b * bits)) & mask)
                    .sum();
                let want_in = cs
                    .iter()
                    .filter(|&&c| (c as usize) >= s * 64 && (c as usize) < (s + 1) * 64)
                    .count() as u64;
                if in_section != want_in {
                    return Err(format!(
                        "row {i} section {s}: counts {in_section} != {want_in}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_incrs_never_costs_more_than_csr_plus_constant() {
    check(0xF3, 30, arb_coo, |coo| {
        let csr = Csr::from_coo(coo);
        let incrs = match InCrs::from_csr(&csr) {
            Ok(x) => x,
            Err(e) => return Err(e.to_string()),
        };
        let (rows, cols) = coo.shape();
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let i = rng.usize_below(rows);
            let j = rng.usize_below(cols);
            let mut c1 = CountSink::default();
            let v1 = csr.locate(i, j, &mut c1);
            let mut c2 = CountSink::default();
            let v2 = incrs.locate(i, j, &mut c2);
            if v1 != v2 {
                return Err(format!("value mismatch at ({i},{j})"));
            }
            // InCRS adds the counter read but skips most of the scan; it can
            // never exceed CRS by more than the one counter access
            if c2.total > c1.total + 1 {
                return Err(format!(
                    "({i},{j}): InCRS {} > CRS {} + 1",
                    c2.total, c1.total
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_storage_words_ordering() {
    // dense >= ELLPACK >= CRS for typical sparse matrices; InCRS adds only
    // counter words over CRS
    check(0xF4, 30, arb_coo, |coo| {
        let (rows, cols) = coo.shape();
        if coo.nnz() == 0 {
            return Ok(());
        }
        let dense = from_coo(spmm_accel::formats::FormatKind::Dense, coo).unwrap();
        let csr = from_coo(spmm_accel::formats::FormatKind::Csr, coo).unwrap();
        let incrs = from_coo(spmm_accel::formats::FormatKind::InCrs, coo).unwrap();
        if dense.storage_words() != rows * cols {
            return Err("dense storage wrong".into());
        }
        let spr = (cols + 255) / 256;
        if incrs.storage_words() != csr.storage_words() + rows * spr {
            return Err(format!(
                "InCRS {} != CRS {} + counters {}",
                incrs.storage_words(),
                csr.storage_words(),
                rows * spr
            ));
        }
        Ok(())
    });
}

/// Every constructor-produced matrix satisfies its own
/// `validate_invariants` — the runtime contract the `strict-invariants`
/// feature debug-asserts at engine/serving boundaries.
#[test]
fn prop_constructed_matrices_always_validate() {
    check(0xF8, 40, arb_coo, |coo| {
        coo.validate_invariants().map_err(|e| format!("coo: {e}"))?;
        let csr = Csr::from_coo(coo);
        csr.validate_invariants().map_err(|e| format!("csr: {e}"))?;
        Csc::from_csr(&csr)
            .validate_invariants()
            .map_err(|e| format!("csc: {e}"))?;
        InCrs::from_csr(&csr)
            .map_err(|e| e.to_string())?
            .validate_invariants()
            .map_err(|e| format!("incrs: {e}"))?;
        Ok(())
    });
}

/// Randomly corrupted indptr/indices are always rejected: flipping a
/// pointer to break monotonicity, pushing an index out of bounds, or
/// truncating the value array must never validate as clean.
#[test]
fn prop_corrupted_structure_never_validates() {
    let gen = |rng: &mut Rng| {
        // ensure at least one nonzero so there is structure to corrupt
        let mut coo = arb_coo(rng);
        while coo.nnz() == 0 {
            coo = arb_coo(rng);
        }
        (Csr::from_coo(&coo), rng.next_u64())
    };
    check(0xF9, 40, gen, |(csr, salt)| {
        let mut rng = Rng::new(*salt);
        let mut bad = csr.clone();
        let kind = rng.usize_below(4);
        match kind {
            0 => {
                // point past the end of the index arrays: a middle pointer
                // breaks monotonicity against the (= nnz) final pointer, the
                // final pointer breaks the nnz agreement — always invalid,
                // unlike a small bump that may form another valid matrix
                let p = 1 + rng.usize_below(bad.row_ptr.len() - 1);
                bad.row_ptr[p] = bad.vals.len() as u32 + 1 + rng.usize_below(9) as u32;
            }
            1 => {
                // push a column index out of bounds
                let e = rng.usize_below(bad.col_idx.len());
                bad.col_idx[e] = bad.cols() as u32 + rng.usize_below(10) as u32;
            }
            2 => {
                // truncate vals so index/value arrays disagree
                bad.vals.pop();
            }
            _ => {
                // drop the final row pointer (length invariant)
                bad.row_ptr.pop();
            }
        }
        if bad.validate_invariants().is_ok() {
            return Err(format!("corruption kind {kind} validated as clean"));
        }
        Ok(())
    });
}

#[test]
fn prop_transpose_is_involution() {
    check(0xF5, 40, arb_coo, |coo| {
        let csr = Csr::from_coo(coo);
        let tt = csr.transpose().transpose();
        if tt.row_ptr != csr.row_ptr || tt.col_idx != csr.col_idx || tt.vals != csr.vals {
            return Err("transpose twice != identity".into());
        }
        Ok(())
    });
}
