//! Integration: formats × datasets × access drivers — the Table I/II
//! machinery end to end on registry-scale (scaled-down) data.

use spmm_accel::access::column::{read_columns_csr, read_columns_incrs};
use spmm_accel::access::locate::{measure, measure_hits};
use spmm_accel::datasets::spec::{table2_by_name, TABLE2};
use spmm_accel::datasets::synth::{generate, uniform};
use spmm_accel::formats::convert::{from_coo, ALL_KINDS};
use spmm_accel::formats::incrs::{InCrs, InCrsParams};
use spmm_accel::formats::traits::{CountSink, FormatKind, SparseMatrix};
use spmm_accel::formats::Csr;

#[test]
fn all_formats_agree_on_a_registry_dataset_slice() {
    // scaled docword: all formats must agree cell-by-cell with CRS
    let mut spec = table2_by_name("docword").unwrap();
    spec.rows = 40;
    spec.cols = 2_000;
    let m = generate(&spec, 9);
    let coo = m.to_coo();
    let mats: Vec<_> = ALL_KINDS
        .iter()
        .map(|&k| from_coo(k, &coo).unwrap())
        .collect();
    let mut rng = spmm_accel::util::rng::Rng::new(4);
    for _ in 0..2_000 {
        let i = rng.usize_below(40);
        let j = rng.usize_below(2_000);
        let want = m.get(i, j);
        for mat in &mats {
            let got = mat.get(i, j);
            // dense reports Some(0.0) where sparse reports None
            let norm = |v: Option<f32>| v.filter(|&x| x != 0.0);
            assert_eq!(norm(got), norm(want), "{:?} at ({i},{j})", mat.kind());
        }
    }
}

#[test]
fn incrs_locate_cost_is_block_bounded_on_every_table2_dataset() {
    for spec in TABLE2 {
        let mut s = spec;
        s.rows = s.rows.min(60); // keep the integration test fast
        let m = generate(&s, 5);
        let incrs = InCrs::from_csr(&m).unwrap();
        let cost = measure_hits(&incrs, 2_000, 7);
        // b/2 + rowptr + counter + val ≈ b/2 + 3 worst case
        let bound = InCrsParams::default().block as f64 / 2.0 + 3.0;
        assert!(
            cost.avg() <= bound,
            "{}: avg {} > bound {bound}",
            spec.name,
            cost.avg()
        );
    }
}

#[test]
fn ma_ratio_grows_with_row_population_across_datasets() {
    // Table II's monotonicity: heavier rows -> bigger InCRS win
    let mut ratios: Vec<(f64, f64)> = Vec::new(); // (nnz_row_avg, ratio)
    for spec in TABLE2 {
        let mut s = spec;
        s.rows = s.rows.min(50);
        let m = generate(&s, 11);
        let incrs = InCrs::from_csr(&m).unwrap();
        let ncols = (m.cols() / 20).max(64).min(m.cols());
        let mut c1 = CountSink::default();
        read_columns_csr(&m, Some(ncols), &mut c1);
        let mut c2 = CountSink::default();
        read_columns_incrs(&incrs, Some(ncols), &mut c2);
        let (_, avg, _) = m.nnz_row_stats();
        ratios.push((avg, c1.total as f64 / c2.total as f64));
    }
    ratios.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // heaviest-row dataset beats lightest-row dataset by a wide margin
    assert!(
        ratios.last().unwrap().1 > 2.0 * ratios.first().unwrap().1,
        "{ratios:?}"
    );
}

#[test]
fn conversion_chain_preserves_matrix() {
    // CRS -> JAD -> LiL -> ELLPACK -> SLL -> CCS -> InCRS -> CRS
    let m = uniform(30, 200, 0.08, 2);
    let coo0 = m.to_coo();
    let chain = [
        FormatKind::Jad,
        FormatKind::Lil,
        FormatKind::Ellpack,
        FormatKind::Sll,
        FormatKind::Csc,
        FormatKind::InCrs,
        FormatKind::Csr,
    ];
    let mut cur = from_coo(FormatKind::Csr, &coo0).unwrap();
    for k in chain {
        cur = spmm_accel::formats::convert(cur.as_ref(), k).unwrap();
    }
    assert_eq!(cur.to_coo().entries, coo0.entries);
}

#[test]
fn incrs_parameter_sweep_tradeoff() {
    // smaller b -> fewer accesses per locate but more counter words
    let m = uniform(40, 4096, 0.05, 3);
    let mut prev_cost = f64::INFINITY;
    let mut prev_storage = 0usize;
    for (s, b) in [(256usize, 64usize), (256, 32), (128, 16)] {
        let incrs = InCrs::from_csr_params(&m, InCrsParams { section: s, block: b }).unwrap();
        let cost = measure(&incrs, 3_000, 1).avg();
        assert!(
            cost < prev_cost * 1.05,
            "b={b}: cost {cost} vs prev {prev_cost}"
        );
        assert!(incrs.storage_words() >= prev_storage);
        prev_cost = cost;
        prev_storage = incrs.storage_words();
    }
}

#[test]
fn csr_binary_search_ablation_uses_fewer_accesses() {
    // the paper's footnote: binary search reduces accesses (but hurts
    // locality — that part is the cache sim's story)
    let m: Csr = uniform(20, 4096, 0.2, 8);
    let mut lin = CountSink::default();
    let mut bin = CountSink::default();
    let mut rng = spmm_accel::util::rng::Rng::new(2);
    for _ in 0..500 {
        let i = rng.usize_below(20);
        let j = rng.usize_below(4096);
        let a = m.locate(i, j, &mut lin);
        let b = m.locate_binary(i, j, &mut bin);
        assert_eq!(a, b);
    }
    assert!(
        bin.total * 10 < lin.total,
        "binary {} vs linear {}",
        bin.total,
        lin.total
    );
}
