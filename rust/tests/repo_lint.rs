//! The repo's own static-analysis gate: `cargo test --test repo_lint`.
//!
//! Runs `detlint` (see `spmm_accel::analysis`) over this crate's sources
//! and fails on any finding — no unordered hash collections in
//! determinism-critical modules (D1), no accumulation-order hazards in
//! kernel modules (D2), no unjustified panics in the serving path (P1),
//! and every registered kernel covered by the all-kernels suite and the
//! README Backends table (C1). Allowlist hygiene is enforced by A0, so a
//! clean run also means zero unjustified or stale `lint: allow` entries.

use std::path::Path;

use spmm_accel::analysis::run_repo_lint;

#[test]
fn repo_is_lint_clean() {
    let report = run_repo_lint(Path::new(env!("CARGO_MANIFEST_DIR")));
    // sanity: the walk really covered the tree and the cross-file layer ran
    // (a silently-empty scan would make a "clean" result meaningless)
    assert!(
        report.files_scanned >= 50,
        "suspiciously few files scanned ({}) — did the src/ walk break?",
        report.files_scanned
    );
    assert!(
        report.lines_scanned > 10_000,
        "suspiciously few lines scanned ({})",
        report.lines_scanned
    );
    assert!(
        report.consistency_checks >= 10,
        "consistency layer performed only {} checks",
        report.consistency_checks
    );
    // the tree carries exactly the documented, justified panic sites
    // (coordinator startup/legacy-shim — see their annotations); every
    // annotation must both carry a reason and still match a finding
    assert!(
        report.allows_used >= 2,
        "expected the documented allow annotations to be in use, saw {}",
        report.allows_used
    );
    assert!(report.is_clean(), "\n{report}");
}
