//! Integration: the batching server under realistic mixed traffic through
//! the `SpmmClient` API — typed errors, B-sharing micro-batch coalescing
//! (bit-identical to uncoalesced execution), sharded row-band execution
//! (bit-identical to unsharded, `ExecFailed` on shard-worker loss without
//! poisoning the server), PJRT-backed workers when artifacts are present,
//! failure injection, per-job kernel overrides, shutdown-drain under
//! concurrent submitters, and router/registry composition.

use std::sync::Arc;
use std::time::Duration;

use spmm_accel::coordinator::{
    route, AccessStrategy, CoalesceConfig, JobError, JobHandle, JobOptions, KernelSpec,
    RegistryHook, RoutingPolicy, Server, ServerConfig, SpmmJob,
};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::{
    Algorithm, CostHint, EngineError, EngineOutput, PreparedB, Registry, SpmmKernel,
};
use spmm_accel::formats::csr::Csr;
use spmm_accel::formats::traits::FormatKind;
use spmm_accel::runtime::Manifest;
use spmm_accel::spmm::plan::Geometry;

fn has_artifacts() -> bool {
    cfg!(feature = "pjrt") && Manifest::default_dir().join("manifest.json").exists()
}

fn server(kernel: KernelSpec, prefer_pjrt: bool, workers: usize) -> Server {
    Server::start(ServerConfig {
        workers,
        queue_depth: 8,
        kernel,
        prefer_pjrt,
        geometry: Geometry { block: 16, pairs: 32, slots: 16 },
        tile_workers: 2,
        artifacts_dir: Manifest::default_dir(),
        coalesce: CoalesceConfig::default(),
        ..Default::default()
    })
}

#[test]
fn mixed_size_traffic_on_cpu_workers() {
    let s = server(KernelSpec::default(), false, 3);
    let client = s.client();
    let mut handles = Vec::new();
    for i in 0..12u64 {
        let n = 16 + (i as usize % 4) * 24;
        let a = Arc::new(uniform(n, n + 8, 0.15, i));
        let b = Arc::new(uniform(n + 8, n, 0.15, i + 100));
        handles.push(
            client
                .job(a, b)
                .verify(true)
                .keep_result(false)
                .submit()
                .unwrap(),
        );
    }
    for res in JobHandle::batch_wait_all(handles) {
        assert!(res.unwrap().max_err.unwrap() < 1e-3);
    }
    let snap = client.metrics();
    assert_eq!(snap.jobs_completed, 12);
    assert_eq!(snap.jobs_failed, 0);
    assert!(snap.p50_us > 0);
    drop(client);
    s.shutdown();
}

/// Acceptance: ≥64 jobs sharing one `B` through `SpmmClient::submit_many`
/// must (a) build `PreparedB` fewer times than there are jobs and (b)
/// produce bit-identical outputs to per-job uncoalesced execution.
#[test]
fn submit_many_coalesces_shared_b_and_stays_bit_identical() {
    const N_JOBS: usize = 64;
    let a_set: Vec<Arc<Csr>> = (0..N_JOBS as u64)
        .map(|i| Arc::new(uniform(24, 48, 0.15, i)))
        .collect();
    let b = Arc::new(uniform(48, 32, 0.2, 999));
    // the inner-InCRS kernel has a real prepare (counter-vector build),
    // so sharing is observable and worth something
    let spec = KernelSpec::Fixed(FormatKind::InCrs, Algorithm::Inner);

    let run = |coalesce: bool, workers: usize| {
        let s = Server::start(ServerConfig {
            workers,
            queue_depth: 32,
            kernel: spec,
            geometry: Geometry { block: 16, pairs: 32, slots: 16 },
            coalesce: CoalesceConfig { enabled: coalesce, ..Default::default() },
            ..Default::default()
        });
        let client = s.client();
        let jobs: Vec<SpmmJob> = a_set
            .iter()
            .enumerate()
            .map(|(i, a)| client.job(Arc::clone(a), Arc::clone(&b)).id(i as u64).build())
            .collect();
        let handles = client.submit_many(jobs);
        let outputs: Vec<_> = JobHandle::batch_wait_all(handles)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        let snap = client.metrics();
        drop(client);
        s.shutdown();
        (outputs, snap)
    };

    // reference: per-job prepare, no sharing
    let (reference, ref_snap) = run(false, 1);
    assert_eq!(ref_snap.prepare_builds, N_JOBS as u64, "{ref_snap:?}");
    assert_eq!(ref_snap.coalesced_jobs, 0);

    // coalesced: shared B amortizes prepare across the batch + LRU cache
    let (outputs, snap) = run(true, 2);
    assert_eq!(snap.jobs_completed, N_JOBS as u64);
    assert!(
        snap.prepare_builds < N_JOBS as u64,
        "coalescing must build fewer PreparedB than jobs: {snap:?}"
    );
    assert!(
        snap.coalesced_jobs + snap.prepare_cache_hits > 0,
        "sharing must actually occur: {snap:?}"
    );

    // results in submission order, bitwise equal to the uncoalesced run
    assert_eq!(outputs.len(), reference.len());
    for (i, (got, want)) in outputs.iter().zip(&reference).enumerate() {
        let (got_c, want_c) = (got.c.as_ref().unwrap(), want.c.as_ref().unwrap());
        assert_eq!(got_c.data, want_c.data, "job {i} diverges from uncoalesced run");
    }
}

/// Sharded serving: the same job at 1 and 4 shards through a real server
/// is bitwise identical, and the per-shard wall/queue metrics populate.
#[test]
fn sharded_serving_is_bit_identical_and_metered() {
    let s = server(KernelSpec::default(), false, 2);
    let client = s.client();
    let a = Arc::new(uniform(96, 64, 0.15, 70));
    let b = Arc::new(uniform(64, 56, 0.15, 71));
    let kernels = [
        (FormatKind::Csr, Algorithm::Tiled),
        (FormatKind::Csr, Algorithm::Gustavson),
        (FormatKind::Csr, Algorithm::GustavsonFast),
        (FormatKind::Csr, Algorithm::Block),
        (FormatKind::InCrs, Algorithm::Inner),
    ];
    for (f, alg) in kernels {
        let run = |shards: usize| {
            client
                .job(Arc::clone(&a), Arc::clone(&b))
                .kernel(f, alg)
                .shards(shards)
                .submit()
                .unwrap()
                .wait()
                .unwrap()
        };
        let base = run(1);
        let sharded = run(4);
        assert!(sharded.shards > 1, "{f:?}/{alg:?}: {}", sharded.shards);
        assert_eq!(
            base.c.as_ref().unwrap().bit_pattern(),
            sharded.c.as_ref().unwrap().bit_pattern(),
            "{f:?}/{alg:?} sharded serving diverges bitwise"
        );
    }
    let snap = client.metrics();
    assert_eq!(snap.sharded_jobs, kernels.len() as u64);
    assert!(snap.shards_executed >= 2 * kernels.len() as u64, "{snap:?}");
    assert_eq!(snap.shard_failures, 0);
    assert!(snap.shard_wall_p50_us > 0, "{snap:?}");
    assert!(snap.shard_queue_p50_us > 0, "{snap:?}");
    drop(client);
    s.shutdown();
}

/// A kernel that always panics in `execute` — registered under an unused
/// registry key via the server's registry hook to inject shard faults.
struct PanicKernel;

impl SpmmKernel for PanicKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Gustavson
    }
    fn format(&self) -> FormatKind {
        FormatKind::Ellpack
    }
    fn name(&self) -> &'static str {
        "panic-injector"
    }
    fn cost_hint(&self, _: &Csr, _: &Csr) -> CostHint {
        CostHint::default()
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        Ok(PreparedB::Csr(Arc::new(b.clone())))
    }
    fn execute(&self, _: &Csr, _: &PreparedB) -> Result<EngineOutput, EngineError> {
        panic!("injected shard fault");
    }
}

/// Fault injection: a panicking shard worker yields `JobError::ExecFailed`
/// on the handle, the server keeps serving subsequent jobs, and shutdown
/// still drains every accepted job.
#[test]
fn panicking_shard_worker_fails_the_job_not_the_server() {
    let hook: RegistryHook = Arc::new(|reg: &mut Registry| {
        reg.register(Arc::new(PanicKernel));
    });
    let s = Server::start(ServerConfig {
        workers: 1,
        queue_depth: 16,
        geometry: Geometry { block: 16, pairs: 32, slots: 16 },
        registry_hook: Some(hook),
        ..Default::default()
    });
    let client = s.client();
    let a = Arc::new(uniform(48, 48, 0.2, 80));

    // the faulting job: its 2 shard workers both panic
    let err = client
        .job(Arc::clone(&a), Arc::clone(&a))
        .kernel(FormatKind::Ellpack, Algorithm::Gustavson)
        .shards(2)
        .submit()
        .unwrap()
        .wait()
        .unwrap_err();
    match &err {
        JobError::ExecFailed(msg) => assert!(msg.contains("shard"), "{msg}"),
        other => panic!("expected ExecFailed, got {other:?}"),
    }
    assert!(!err.is_transient(), "a lost shard is a job defect, not backpressure");

    // the single server worker survived and serves both sharded and
    // unsharded follow-up traffic
    for shards in [1usize, 2] {
        let out = client
            .job(Arc::clone(&a), Arc::clone(&a))
            .shards(shards)
            .keep_result(false)
            .submit()
            .unwrap()
            .wait();
        assert!(out.is_ok(), "server poisoned after shard fault (shards={shards})");
    }
    let snap = client.metrics();
    assert!(snap.shard_failures >= 1, "{snap:?}");
    assert_eq!(snap.jobs_failed, 1, "{snap:?}");

    // shutdown still drains: accepted-but-unserved jobs all get answers
    let pending: Vec<SpmmJob> = (0..6)
        .map(|i| {
            client
                .job(Arc::clone(&a), Arc::clone(&a))
                .id(100 + i)
                .keep_result(false)
                .build()
        })
        .collect();
    let handles = client.submit_many(pending);
    drop(client);
    s.shutdown();
    for h in handles {
        match h.wait() {
            Ok(_) | Err(JobError::Shutdown) => {}
            Err(e) => panic!("stranded job after shard fault: {e}"),
        }
    }
}

#[test]
fn shutdown_drains_with_concurrent_submitters() {
    let s = server(KernelSpec::default(), false, 2);
    let client = s.client();
    let a = Arc::new(uniform(32, 32, 0.2, 1));
    let barrier = Arc::new(std::sync::Barrier::new(4));
    let mut threads = Vec::new();
    for t in 0..3u64 {
        let client = client.clone();
        let a = Arc::clone(&a);
        let barrier = Arc::clone(&barrier);
        threads.push(std::thread::spawn(move || {
            barrier.wait();
            let mut outcomes = Vec::new();
            for i in 0..20u64 {
                let job = client.job(Arc::clone(&a), Arc::clone(&a)).id(t * 100 + i).build();
                match client.submit(job) {
                    Ok(h) => outcomes.push(h.wait()),
                    Err(e) => {
                        // the server closed under us — typed, not a panic
                        assert_eq!(e, JobError::Shutdown);
                        break;
                    }
                }
            }
            outcomes
        }));
    }
    barrier.wait();
    // let some traffic land, then close while submitters are still racing
    std::thread::sleep(Duration::from_millis(10));
    drop(client);
    s.shutdown();
    let mut completed = 0u64;
    for t in threads {
        for res in t.join().unwrap() {
            match res {
                Ok(_) => completed += 1,
                // accepted but raced the close: drained with Shutdown,
                // never stranded (this join alone proves no hang)
                Err(JobError::Shutdown) => {}
                Err(e) => panic!("unexpected job error: {e}"),
            }
        }
    }
    assert!(completed > 0, "some jobs must have completed before the close");
}

#[test]
fn pjrt_workers_serve_verified_jobs() {
    if !has_artifacts() {
        eprintln!("skipping: no artifacts (or built without --features pjrt)");
        return;
    }
    let s = server(KernelSpec::default(), true, 2);
    let client = s.client();
    let a = Arc::new(uniform(80, 100, 0.1, 1));
    let b = Arc::new(uniform(100, 70, 0.1, 2));
    let mut handles = Vec::new();
    for _ in 0..6 {
        handles.push(
            client
                .job(a.clone(), b.clone())
                .verify(true)
                .keep_result(false)
                .submit()
                .unwrap(),
        );
    }
    for res in JobHandle::batch_wait_all(handles) {
        let out = res.unwrap();
        assert_eq!(out.backend, "pjrt");
        assert!(out.max_err.unwrap() < 1e-3);
    }
    drop(client);
    s.shutdown();
}

#[test]
fn failure_injection_bad_dimensions_dont_poison_workers() {
    let s = server(KernelSpec::default(), false, 2);
    let client = s.client();
    let good_a = Arc::new(uniform(24, 24, 0.2, 3));
    let bad_b = Arc::new(uniform(17, 24, 0.2, 4)); // inner mismatch
    // interleave good and bad jobs
    let mut handles = Vec::new();
    for i in 0..10u64 {
        let b = if i % 2 == 0 { good_a.clone() } else { bad_b.clone() };
        handles.push((i, client.job(good_a.clone(), b).id(i).submit().unwrap()));
    }
    for (i, h) in handles {
        let res = h.wait();
        if i % 2 == 0 {
            assert!(res.is_ok(), "job {i}");
        } else {
            assert_eq!(
                res.unwrap_err(),
                JobError::ShapeMismatch { a: (24, 24), b: (17, 24) },
                "job {i}"
            );
        }
    }
    let snap = client.metrics();
    assert_eq!(snap.jobs_completed, 5);
    assert_eq!(snap.jobs_failed, 5);
    drop(client);
    s.shutdown();
}

#[test]
fn router_strategy_matches_table2_datasets() {
    let policy = RoutingPolicy::default();
    // docword-like B: InCRS pays off (est ratio ~14)
    let docword = uniform(128, 12_000, 0.04, 1);
    let r = route(&docword, true, false, &policy);
    assert_eq!(r.access, AccessStrategy::ColumnInCrs);
    assert_eq!(r.kernel, (FormatKind::InCrs, Algorithm::Inner));
    assert!(r.estimated_ma_ratio > 10.0);
    // near-empty B: plain CRS column scans are fine
    let sparse = uniform(128, 2_000, 0.002, 2);
    let r2 = route(&sparse, true, false, &policy);
    assert_eq!(r2.access, AccessStrategy::ColumnCrs);
    assert_eq!(r2.kernel, (FormatKind::Csr, Algorithm::Inner));
}

#[test]
fn mixed_kernel_traffic_through_one_server() {
    // one server, four different kernels chosen per job — the registry
    // dispatch the old EngineKind enum couldn't express
    let s = server(KernelSpec::default(), false, 2);
    let client = s.client();
    let a = Arc::new(uniform(40, 56, 0.15, 5));
    let b = Arc::new(uniform(56, 44, 0.15, 6));
    let kernels = [
        (FormatKind::Csr, Algorithm::Block, "cpu"),
        (FormatKind::Csr, Algorithm::Gustavson, "gustavson"),
        (FormatKind::InCrs, Algorithm::Inner, "inner-incrs"),
        (FormatKind::Csr, Algorithm::Tiled, "tiled"),
    ];
    let handles: Vec<_> = kernels
        .iter()
        .map(|&(f, alg, _)| {
            client
                .job(a.clone(), b.clone())
                .verify(true)
                .keep_result(false)
                .kernel(f, alg)
                .submit()
                .unwrap()
        })
        .collect();
    for (res, &(_, _, name)) in JobHandle::batch_wait_all(handles).into_iter().zip(&kernels) {
        let out = res.unwrap();
        assert_eq!(out.backend, name);
        assert!(out.max_err.unwrap() < 1e-3, "{name}");
    }
    drop(client);
    s.shutdown();
}

#[test]
fn auto_spec_serves_mixed_shapes() {
    let s = server(KernelSpec::Auto, false, 2);
    let client = s.client();
    let mut handles = Vec::new();
    for i in 0..6u64 {
        let n = 24 + (i as usize % 3) * 16;
        let a = Arc::new(uniform(n, n, 0.1 + 0.05 * (i % 2) as f64, i + 40));
        let b = Arc::new(uniform(n, n, 0.1, i + 60));
        handles.push(client.job(a, b).verify(true).keep_result(false).submit().unwrap());
    }
    for res in JobHandle::batch_wait_all(handles) {
        let out = res.unwrap();
        assert!(out.max_err.unwrap() < 1e-3);
        assert_ne!(out.backend, "dense");
    }
    drop(client);
    s.shutdown();
}

#[test]
fn throughput_scales_with_workers() {
    // wall-clock assertions are flaky in CI; assert work conservation
    // instead: N workers complete the same batch, each job exactly once.
    for workers in [1usize, 4] {
        let s = server(KernelSpec::default(), false, workers);
        let client = s.client();
        let a = Arc::new(uniform(48, 48, 0.2, 9));
        let jobs = (0..16u64).map(|i| {
            client.job(a.clone(), a.clone()).id(i).keep_result(false).build()
        });
        let stream = client.stream(jobs);
        let mut ids: Vec<u64> = stream
            .map(|(id, res)| {
                res.unwrap();
                id
            })
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..16).collect::<Vec<_>>());
        drop(client);
        s.shutdown();
    }
}

#[test]
fn legacy_submit_shim_still_serves() {
    // the pre-client surface stays for one release: raw Receiver<JobResult>
    let s = server(KernelSpec::default(), false, 1);
    let a = Arc::new(uniform(20, 20, 0.3, 21));
    let rx = s.submit(SpmmJob::new(7, a.clone(), a).with_opts(JobOptions {
        verify: true,
        keep_result: false,
        ..Default::default()
    }));
    let res = rx.recv().unwrap();
    assert_eq!(res.id, 7);
    assert!(res.result.unwrap().max_err.unwrap() < 1e-3);
    s.shutdown();
}

#[test]
fn outer_kernel_jobs_log_selection_observations() {
    // the kernel-observation log must cover newly registered algorithms
    // with no coordinator changes: run outer-product jobs and find them
    // in `Metrics::kernel_log` with an honest cost hint attached
    let s = server(
        KernelSpec::Fixed(FormatKind::Csc, Algorithm::OuterProduct),
        false,
        1,
    );
    let client = s.client();
    for i in 0..3u64 {
        let a = Arc::new(uniform(32, 40, 0.1, i + 80));
        let b = Arc::new(uniform(40, 24, 0.1, i + 90));
        let out = client
            .job(a, b)
            .verify(true)
            .keep_result(false)
            .submit()
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(out.backend, "outer");
        assert!(out.max_err.unwrap() < 1e-3);
    }
    assert_eq!(s.metrics.snapshot().kernel_observations, 3);
    let log = s.metrics.kernel_log();
    assert!(
        log.iter().any(|o| o.algorithm == Algorithm::OuterProduct
            && o.format == FormatKind::Csc
            && o.cost_hint > 0.0),
        "no outer-product observation in {log:?}"
    );
    drop(client);
    s.shutdown();
}
