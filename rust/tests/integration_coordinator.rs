//! Integration: the batching server under realistic mixed traffic,
//! including PJRT-backed workers when artifacts are present, failure
//! injection, per-job kernel overrides, and router/registry composition.

use std::sync::Arc;

use spmm_accel::coordinator::{
    route, AccessStrategy, JobOptions, KernelSpec, RoutingPolicy, Server,
    ServerConfig, SpmmJob,
};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::Algorithm;
use spmm_accel::formats::traits::FormatKind;
use spmm_accel::runtime::Manifest;
use spmm_accel::spmm::plan::Geometry;

fn has_artifacts() -> bool {
    cfg!(feature = "pjrt") && Manifest::default_dir().join("manifest.json").exists()
}

fn server(kernel: KernelSpec, prefer_pjrt: bool, workers: usize) -> Server {
    Server::start(ServerConfig {
        workers,
        queue_depth: 8,
        kernel,
        prefer_pjrt,
        geometry: Geometry { block: 16, pairs: 32, slots: 16 },
        tile_workers: 2,
        artifacts_dir: Manifest::default_dir(),
    })
}

#[test]
fn mixed_size_traffic_on_cpu_workers() {
    let s = server(KernelSpec::default(), false, 3);
    let mut rxs = Vec::new();
    for i in 0..12u64 {
        let n = 16 + (i as usize % 4) * 24;
        let a = Arc::new(uniform(n, n + 8, 0.15, i));
        let b = Arc::new(uniform(n + 8, n, 0.15, i + 100));
        rxs.push(s.submit(SpmmJob::new(i, a, b).with_opts(JobOptions {
            verify: true,
            keep_result: false,
            kernel: None,
        })));
    }
    for rx in rxs {
        let out = rx.recv().unwrap().result.unwrap();
        assert!(out.max_err.unwrap() < 1e-3);
    }
    let snap = s.metrics.snapshot();
    assert_eq!(snap.jobs_completed, 12);
    assert_eq!(snap.jobs_failed, 0);
    assert!(snap.p50_us > 0);
    s.shutdown();
}

#[test]
fn pjrt_workers_serve_verified_jobs() {
    if !has_artifacts() {
        eprintln!("skipping: no artifacts (or built without --features pjrt)");
        return;
    }
    let s = server(KernelSpec::default(), true, 2);
    let a = Arc::new(uniform(80, 100, 0.1, 1));
    let b = Arc::new(uniform(100, 70, 0.1, 2));
    let mut rxs = Vec::new();
    for i in 0..6u64 {
        rxs.push(s.submit(SpmmJob::new(i, a.clone(), b.clone()).with_opts(
            JobOptions {
                verify: true,
                keep_result: false,
                kernel: None,
            },
        )));
    }
    for rx in rxs {
        let out = rx.recv().unwrap().result.unwrap();
        assert_eq!(out.backend, "pjrt");
        assert!(out.max_err.unwrap() < 1e-3);
    }
    s.shutdown();
}

#[test]
fn failure_injection_bad_dimensions_dont_poison_workers() {
    let s = server(KernelSpec::default(), false, 2);
    let good_a = Arc::new(uniform(24, 24, 0.2, 3));
    let bad_b = Arc::new(uniform(17, 24, 0.2, 4)); // inner mismatch
    // interleave good and bad jobs
    let mut rxs = Vec::new();
    for i in 0..10u64 {
        let job = if i % 2 == 0 {
            SpmmJob::new(i, good_a.clone(), good_a.clone())
        } else {
            SpmmJob::new(i, good_a.clone(), bad_b.clone())
        };
        rxs.push((i, s.submit(job)));
    }
    for (i, rx) in rxs {
        let res = rx.recv().unwrap();
        if i % 2 == 0 {
            assert!(res.result.is_ok(), "job {i}");
        } else {
            assert!(res.result.is_err(), "job {i}");
        }
    }
    let snap = s.metrics.snapshot();
    assert_eq!(snap.jobs_completed, 5);
    assert_eq!(snap.jobs_failed, 5);
    s.shutdown();
}

#[test]
fn router_strategy_matches_table2_datasets() {
    let policy = RoutingPolicy::default();
    // docword-like B: InCRS pays off (est ratio ~14)
    let docword = uniform(128, 12_000, 0.04, 1);
    let r = route(&docword, true, false, &policy);
    assert_eq!(r.access, AccessStrategy::ColumnInCrs);
    assert_eq!(r.kernel, (FormatKind::InCrs, Algorithm::Inner));
    assert!(r.estimated_ma_ratio > 10.0);
    // near-empty B: plain CRS column scans are fine
    let sparse = uniform(128, 2_000, 0.002, 2);
    let r2 = route(&sparse, true, false, &policy);
    assert_eq!(r2.access, AccessStrategy::ColumnCrs);
    assert_eq!(r2.kernel, (FormatKind::Csr, Algorithm::Inner));
}

#[test]
fn mixed_kernel_traffic_through_one_server() {
    // one server, four different kernels chosen per job — the registry
    // dispatch the old EngineKind enum couldn't express
    let s = server(KernelSpec::default(), false, 2);
    let a = Arc::new(uniform(40, 56, 0.15, 5));
    let b = Arc::new(uniform(56, 44, 0.15, 6));
    let kernels = [
        (FormatKind::Csr, Algorithm::Block, "cpu"),
        (FormatKind::Csr, Algorithm::Gustavson, "gustavson"),
        (FormatKind::InCrs, Algorithm::Inner, "inner-incrs"),
        (FormatKind::Csr, Algorithm::Tiled, "tiled"),
    ];
    let rxs: Vec<_> = kernels
        .iter()
        .enumerate()
        .map(|(i, &(f, alg, _))| {
            s.submit(
                SpmmJob::new(i as u64, a.clone(), b.clone())
                    .with_opts(JobOptions {
                        verify: true,
                        keep_result: false,
                        kernel: None,
                    })
                    .with_kernel(f, alg),
            )
        })
        .collect();
    for (rx, &(_, _, name)) in rxs.into_iter().zip(&kernels) {
        let out = rx.recv().unwrap().result.unwrap();
        assert_eq!(out.backend, name);
        assert!(out.max_err.unwrap() < 1e-3, "{name}");
    }
    s.shutdown();
}

#[test]
fn auto_spec_serves_mixed_shapes() {
    let s = server(KernelSpec::Auto, false, 2);
    let mut rxs = Vec::new();
    for i in 0..6u64 {
        let n = 24 + (i as usize % 3) * 16;
        let a = Arc::new(uniform(n, n, 0.1 + 0.05 * (i % 2) as f64, i + 40));
        let b = Arc::new(uniform(n, n, 0.1, i + 60));
        rxs.push(s.submit(SpmmJob::new(i, a, b).with_opts(JobOptions {
            verify: true,
            keep_result: false,
            kernel: None,
        })));
    }
    for rx in rxs {
        let out = rx.recv().unwrap().result.unwrap();
        assert!(out.max_err.unwrap() < 1e-3);
        assert_ne!(out.backend, "dense");
    }
    s.shutdown();
}

#[test]
fn throughput_scales_with_workers() {
    // wall-clock assertions are flaky in CI; assert work conservation
    // instead: N workers complete the same batch, each job exactly once.
    for workers in [1usize, 4] {
        let s = server(KernelSpec::default(), false, workers);
        let a = Arc::new(uniform(48, 48, 0.2, 9));
        let rxs: Vec<_> = (0..16u64)
            .map(|i| {
                s.submit(SpmmJob::new(i, a.clone(), a.clone()).with_opts(
                    JobOptions {
                        verify: false,
                        keep_result: false,
                        kernel: None,
                    },
                ))
            })
            .collect();
        let mut ids: Vec<u64> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..16).collect::<Vec<_>>());
        s.shutdown();
    }
}
