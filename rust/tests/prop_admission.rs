//! Traffic-resilience property suite (`coordinator::admission` +
//! `engine::remote` re-admission): an armed admission gate sheds with a
//! typed `Overloaded { retry_after }` instead of queueing unboundedly,
//! but NEVER drops a job it admitted — every accepted handle resolves,
//! bit-identically across the whole burst. Priority classes respect the
//! fair queue's explicit starvation bound (a low job overtaken by a
//! high-priority stream is still served before the stream drains), and a
//! socket worker that dies mid-band is re-admitted on a later job with a
//! fresh handshake, producing bit-identical results.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use spmm_accel::coordinator::{
    AdmissionConfig, CoalesceConfig, JobError, JobOptions, Priority, Server, ServerConfig,
    SpmmJob,
};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::remote::serve;
use spmm_accel::engine::{
    shard, Algorithm, CostHint, EngineError, EngineOutput, GustavsonKernel, PreparedB,
    Registry, RetryPolicy, ShardConfig, SocketTransport, SpmmKernel,
};
use spmm_accel::formats::csr::Csr;
use spmm_accel::formats::traits::FormatKind;
use spmm_accel::spmm::plan::Geometry;

const BLOCK: usize = 16;

fn geometry() -> Geometry {
    Geometry { block: 8, pairs: 16, slots: 8 }
}

/// A server whose gate sheds on ANY predicted queue delay once the
/// service-rate estimate has trained — the harshest admission setting.
fn zero_budget_server(workers: usize, depth: usize) -> Server {
    Server::start(ServerConfig {
        workers,
        queue_depth: depth,
        geometry: geometry(),
        admission: AdmissionConfig {
            max_queue_delay: Some(Duration::ZERO),
            ..Default::default()
        },
        ..Default::default()
    })
}

// ----------------------------------------------------------- admission

/// (a) Load shedding rejects at the door, never after: under a burst that
/// saturates a zero-budget gate, every job the gate ADMITTED completes,
/// and all accepted runs of the identical multiply are bit-identical.
/// Accounting is exact: `jobs_shed` counts the sheds, `jobs_completed`
/// the admissions.
#[test]
fn admitted_jobs_are_never_dropped_under_saturation() {
    let s = zero_budget_server(2, 16);
    let client = s.client();
    let a = Arc::new(uniform(64, 64, 0.4, 11));
    // train the service-rate estimate (an untrained gate admits all)
    let trained = client
        .submit(SpmmJob::new(0, a.clone(), a.clone()))
        .expect("untrained gate admits")
        .wait()
        .expect("training job");
    let want = trained.c.expect("keep_result defaults on").bit_pattern();

    let mut accepted = Vec::new();
    let mut shed = 0u64;
    for i in 1..=24u64 {
        let job = SpmmJob::new(i, a.clone(), a.clone()).with_tenant((i % 3) as u32);
        match client.submit(job) {
            Ok(h) => accepted.push(h),
            Err(JobError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("unexpected rejection: {e}"),
        }
    }
    assert!(shed >= 1, "zero-budget gate never shed under a 24-job burst");
    assert!(!accepted.is_empty(), "gate shed everything, including at zero backlog");
    let n = accepted.len() as u64;
    for h in accepted {
        let out = h.wait().expect("admitted job was dropped");
        assert_eq!(
            out.c.expect("result kept").bit_pattern(),
            want,
            "accepted jobs diverged under load shedding"
        );
    }
    let snap = s.metrics.snapshot();
    assert_eq!(snap.jobs_shed, shed);
    assert_eq!(snap.jobs_completed, n + 1);
    assert_eq!(snap.jobs_failed, 0);
    s.shutdown();
}

/// (b) Sheds are typed and actionable: `Overloaded` carries a nonzero
/// `retry_after`, classifies as transient, and the bounded-wait path
/// (`submit_within`) converts the shed into admission once the backlog
/// drains instead of making the caller hand-roll a retry loop.
#[test]
fn sheds_are_typed_and_the_bounded_wait_path_recovers() {
    let s = zero_budget_server(1, 16);
    let client = s.client();
    let a = Arc::new(uniform(64, 64, 0.4, 21));
    client
        .submit(SpmmJob::new(0, a.clone(), a.clone()))
        .expect("untrained gate admits")
        .wait()
        .expect("training job");

    let mut handles = Vec::new();
    let mut sheds = Vec::new();
    for i in 1..=12u64 {
        match client.submit(SpmmJob::new(i, a.clone(), a.clone())) {
            Ok(h) => handles.push(h),
            Err(e) => sheds.push(e),
        }
    }
    assert!(!sheds.is_empty(), "burst never shed");
    for e in &sheds {
        assert!(matches!(e, JobError::Overloaded { .. }), "untyped shed: {e}");
        assert!(e.is_transient(), "sheds must invite a retry: {e}");
        let hint = e.retry_after().expect("Overloaded carries retry_after");
        assert!(hint > Duration::ZERO, "zero retry hint");
        let msg = format!("{e}");
        assert!(msg.contains("retry"), "shed message hides the hint: {msg}");
    }
    // the bounded-wait path rides out the backlog the plain submit shed on
    let out = client
        .submit_within(SpmmJob::new(99, a.clone(), a.clone()), Duration::from_secs(30))
        .expect("bounded wait should admit once the queue drains")
        .wait()
        .expect("admitted job completes");
    assert!(out.c.is_some());
    for h in handles {
        h.wait().expect("admitted job was dropped");
    }
    s.shutdown();
}

/// (c) The starvation bound is real: a low-priority job buried under a
/// stream of high-priority work is bypassed at most `starvation_bound`
/// times, so it completes while high-priority jobs are still pending —
/// overtaken, but never starved.
#[test]
fn low_priority_is_bypassed_at_most_the_starvation_bound() {
    let s = Server::start(ServerConfig {
        workers: 1,
        queue_depth: 32,
        geometry: geometry(),
        // tiny batch window: the high stream needs many take_batch rounds
        // to drain, so the low job's bypass counter actually climbs
        coalesce: CoalesceConfig { enabled: true, max_batch: 2, cache_capacity: 8 },
        ..Default::default()
    });
    let blocker_a = Arc::new(uniform(96, 96, 0.4, 31));
    let blocker = s.submit(SpmmJob::new(0, blocker_a.clone(), blocker_a));
    // queued while the blocker executes: one low job, then a 20-job
    // high-priority stream (shared B, distinct from the low's)
    let low_a = Arc::new(uniform(96, 96, 0.4, 32));
    let low = s.submit(
        SpmmJob::new(1, low_a.clone(), low_a)
            .with_opts(JobOptions { priority: Priority::Low, ..Default::default() }),
    );
    let high_a = Arc::new(uniform(96, 96, 0.4, 33));
    let highs: Vec<_> = (2..22u64)
        .map(|i| {
            s.submit(
                SpmmJob::new(i, high_a.clone(), high_a.clone())
                    .with_opts(JobOptions { priority: Priority::High, ..Default::default() }),
            )
        })
        .collect();
    assert!(blocker.recv().unwrap().result.is_ok());
    assert!(low.recv().unwrap().result.is_ok());
    // with bound 4 and window 2 the low is served by roughly the fifth
    // batch — ten-ish of the twenty highs must still be queued behind it
    let pending_highs = highs.iter().filter(|rx| rx.try_recv().is_err()).count();
    assert!(
        pending_highs >= 1,
        "low-priority job waited for the entire high-priority stream"
    );
    for rx in highs {
        assert!(rx.recv().unwrap().result.is_ok());
    }
    s.shutdown();
}

// -------------------------------------------------------- re-admission

/// Dies on its first execute only, then behaves exactly like the kernel
/// it shadows — a worker crash that a later re-admission should survive.
struct FlakyKernel {
    fail_once: Arc<AtomicBool>,
}

impl SpmmKernel for FlakyKernel {
    fn algorithm(&self) -> Algorithm {
        Algorithm::Gustavson
    }
    fn format(&self) -> FormatKind {
        FormatKind::Csr
    }
    fn name(&self) -> &'static str {
        "flaky-gustavson"
    }
    fn cost_hint(&self, a: &Csr, b: &Csr) -> CostHint {
        GustavsonKernel.cost_hint(a, b)
    }
    fn prepare(&self, b: &Csr) -> Result<PreparedB, EngineError> {
        GustavsonKernel.prepare(b)
    }
    fn execute(&self, a: &Csr, b: &PreparedB) -> Result<EngineOutput, EngineError> {
        if self.fail_once.swap(false, Ordering::SeqCst) {
            panic!("injected worker crash");
        }
        GustavsonKernel.execute(a, b)
    }
}

/// (d) A worker that crashed mid-band is re-admitted by a later job: the
/// circuit breaker re-dials, re-handshakes, re-replicates the staged
/// operand, and the revived run is bit-identical to the local one —
/// metered as `workers_readmitted`.
#[test]
fn revived_worker_rejoins_and_stays_bit_identical() {
    let mut reg = Registry::with_default_kernels(
        Geometry { block: BLOCK, pairs: 32, slots: 16 },
        2,
    );
    let fail_once = Arc::new(AtomicBool::new(true));
    reg.register(Arc::new(FlakyKernel { fail_once }));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind worker listener");
    let addr = listener.local_addr().expect("worker addr").to_string();
    let reg = Arc::new(reg);
    let serve_reg = Arc::clone(&reg);
    std::thread::spawn(move || {
        let _ = serve(listener, serve_reg);
    });
    let socket = SocketTransport::connect_with(&[addr], RetryPolicy {
        band_timeout: Duration::from_secs(30),
        retry_budget: 1,
        hedge_after: Duration::from_secs(600),
    })
    .expect("connect");

    let kernel = GustavsonKernel;
    let a = uniform(64, 48, 0.2, 41);
    let b = uniform(48, 40, 0.2, 42);
    let prepared = kernel.prepare(&b).expect("prepare");
    let cfg = ShardConfig { shards: 2, block: BLOCK };
    let want = shard::execute(&kernel, &a, Some(&b), &prepared, cfg).expect("local run");

    // first job: the only worker panics mid-band and the transport fails
    // typed — nothing left to place the bands on
    shard::execute_with(&socket, &kernel, &a, Some(&b), &prepared, cfg)
        .expect_err("sole worker died — the job cannot complete");
    assert_eq!(socket.live_workers(), 0, "dead worker still counted live");

    // second job: the breaker probes, re-handshakes, re-stages B, and the
    // revived worker produces the bit-identical result
    let out = shard::execute_with(&socket, &kernel, &a, Some(&b), &prepared, cfg)
        .expect("revived worker serves again");
    assert_eq!(
        out.c.bit_pattern(),
        want.c.bit_pattern(),
        "revived worker diverges from the local run"
    );
    assert!(out.counters.workers_readmitted >= 1, "{:?}", out.counters);
    assert!(
        out.counters.prepare_replications >= 1,
        "re-admission must re-stage B (the old staging died with the socket): {:?}",
        out.counters
    );
    assert_eq!(socket.live_workers(), 1);
}
