//! Property tests for the unified execution layer (via `util::ptest`):
//!
//! 1. every format in `formats::ALL_KINDS` round-trips through canonical
//!    COO on random matrices (entries, shape, nnz preserved), and
//! 2. every kernel registered in the default registry agrees with the
//!    `spmm::dense` oracle on random matrix products.

use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::{Algorithm, Registry, SpmmKernel, TiledConfig, TiledKernel};
use spmm_accel::formats::traits::SparseMatrix;
use spmm_accel::formats::{from_coo, Coo, ALL_KINDS};
use spmm_accel::spmm::dense::multiply as dense_ref;
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::ptest::check;
use spmm_accel::util::rng::Rng;

/// Random COO with dimensions in [1, 40] and any density in [0, 0.5].
fn gen_coo(rng: &mut Rng) -> Coo {
    let rows = rng.usize_below(40) + 1;
    let cols = rng.usize_below(40) + 1;
    let density = rng.f64() * 0.5;
    let mut entries = Vec::new();
    for i in 0..rows {
        for j in 0..cols {
            if rng.f64() < density {
                // non-zero values only: formats drop exact zeros
                entries.push((i as u32, j as u32, rng.f32() + 0.25));
            }
        }
    }
    Coo::new(rows, cols, entries)
}

#[test]
fn every_format_roundtrips_through_coo_on_random_matrices() {
    check(0xF0A7, 40, gen_coo, |coo| {
        for kind in ALL_KINDS {
            let m = from_coo(kind, coo)
                .map_err(|e| format!("{kind:?} build failed: {e}"))?;
            if m.kind() != kind {
                return Err(format!("{kind:?} reports kind {:?}", m.kind()));
            }
            if m.shape() != coo.shape() || m.nnz() != coo.nnz() {
                return Err(format!(
                    "{kind:?} lost metadata: {:?}/{} vs {:?}/{}",
                    m.shape(),
                    m.nnz(),
                    coo.shape(),
                    coo.nnz()
                ));
            }
            let back = m.to_coo();
            if back.entries != coo.entries {
                return Err(format!(
                    "{kind:?} round-trip changed entries ({} vs {})",
                    back.entries.len(),
                    coo.entries.len()
                ));
            }
        }
        Ok(())
    });
}

/// Random compatible (A, B) pair for SpMM.
fn gen_pair(rng: &mut Rng) -> (spmm_accel::formats::Csr, spmm_accel::formats::Csr) {
    let m = rng.usize_below(48) + 4;
    let k = rng.usize_below(48) + 4;
    let n = rng.usize_below(48) + 4;
    let da = 0.05 + rng.f64() * 0.3;
    let db = 0.05 + rng.f64() * 0.3;
    let seed = rng.next_u64();
    (uniform(m, k, da, seed), uniform(k, n, db, seed ^ 0xDEAD))
}

#[test]
fn every_registered_kernel_agrees_with_the_dense_oracle() {
    let registry = Registry::with_default_kernels(
        Geometry { block: 16, pairs: 32, slots: 16 },
        2,
    );
    assert!(registry.len() >= 8, "default registry too small: {registry:?}");
    check(0xBEEF, 15, gen_pair, |(a, b)| {
        let want = dense_ref(a, b);
        for kernel in registry.kernels() {
            let out = kernel
                .run(a, b)
                .map_err(|e| format!("{} failed: {e}", kernel.name()))?;
            let diff = out.c.max_abs_diff(&want);
            if diff >= 1e-3 {
                return Err(format!(
                    "kernel {}/{} diverges from oracle by {diff}",
                    kernel.format().name(),
                    kernel.algorithm().name()
                ));
            }
            if out.c.shape() != want.shape() {
                return Err(format!("{} wrong shape", kernel.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn parallel_tiled_kernel_is_bit_identical_to_serial_on_random_inputs() {
    let serial = TiledKernel::new(TiledConfig { block: 16, workers: 1 });
    let parallel = TiledKernel::new(TiledConfig { block: 16, workers: 4 });
    check(0x71AD, 12, gen_pair, |(a, b)| {
        // EngineError -> String via From, no manual round-trip
        let c1 = serial.run(a, b)?;
        let c4 = parallel.run(a, b)?;
        if c1.c.data != c4.c.data {
            return Err("parallel tiled result differs bitwise from serial".into());
        }
        Ok(())
    });
}

/// With `strict-invariants` on, a structurally corrupt operand must be
/// caught at the `SpmmKernel::run` boundary before any kernel reads it.
/// (Without the feature the check closure never runs — see the
/// `formats::strict_check` no-op test.)
#[cfg(feature = "strict-invariants")]
#[test]
#[should_panic(expected = "strict-invariants violated at SpmmKernel::run(B)")]
fn strict_builds_reject_corrupt_operands_at_the_run_boundary() {
    use spmm_accel::engine::{GustavsonKernel, SpmmKernel};
    let a = uniform(8, 8, 0.4, 1);
    let mut b = uniform(8, 8, 0.4, 2);
    b.col_idx[0] = 99; // out of bounds: structurally corrupt
    let _ = GustavsonKernel.run(&a, &b);
}

#[test]
fn registry_resolves_the_contracted_kernels() {
    use spmm_accel::formats::traits::FormatKind;
    let registry = Registry::with_default_kernels(Geometry::default(), 1);
    // the acceptance surface: ≥3 algorithms over ≥3 formats
    for (f, alg) in [
        (FormatKind::Csr, Algorithm::Gustavson),
        (FormatKind::Csr, Algorithm::GustavsonFast),
        (FormatKind::Csr, Algorithm::Inner),
        (FormatKind::InCrs, Algorithm::Inner),
        (FormatKind::Dense, Algorithm::Dense),
        (FormatKind::Csr, Algorithm::Tiled),
        (FormatKind::Csr, Algorithm::Block),
        (FormatKind::Csc, Algorithm::OuterProduct),
    ] {
        assert!(
            registry.resolve(f, alg).is_some(),
            "missing kernel for {f:?}/{alg:?}"
        );
    }
}
