//! Bit-reproducibility property suite for sharded row-band execution
//! (`engine::shard`): for random sparse A/B and EVERY kernel in the
//! default registry, the merged shard output at shard counts {1, 2, 3, 5,
//! 8} is bit-identical (exact bit compare on every output value) to both
//! the 1-shard run and the unsharded `kernel.execute`, including
//! empty-row-band and shards-greater-than-rows edge cases.
//!
//! Values are `f32` throughout the crate (`Dense::data`), so "exact bit
//! compare" is `f32::to_bits` per element — any reassociation of a
//! floating-point reduction, dropped row, or double-write shows up as a
//! bit diff.

use std::sync::Arc;

use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::{
    shard, Algorithm, Registry, ShardConfig, ShardPlanner, ShardedKernel, SpmmKernel,
};
use spmm_accel::formats::coo::Coo;
use spmm_accel::formats::csr::Csr;
use spmm_accel::formats::dense::Dense;
use spmm_accel::formats::traits::{FormatKind, SparseMatrix};
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::ptest::check;
use spmm_accel::util::rng::Rng;

/// Band alignment shared by the registry's blocked kernels (tiled, accel)
/// and the shard planner — the bit-reproducibility precondition.
const BLOCK: usize = 16;
const SHARD_COUNTS: [usize; 5] = [1, 2, 3, 5, 8];

fn registry() -> Registry {
    Registry::with_default_kernels(Geometry { block: BLOCK, pairs: 32, slots: 16 }, 2)
}

fn bits(c: &Dense) -> Vec<u32> {
    c.bit_pattern()
}

/// Random compatible (A, B): enough rows for several block rows, mixed
/// densities including very sparse (empty block rows appear naturally).
fn gen_pair(rng: &mut Rng) -> (Csr, Csr) {
    let m = rng.usize_below(80) + 2;
    let k = rng.usize_below(48) + 4;
    let n = rng.usize_below(48) + 4;
    let da = rng.f64() * 0.3;
    let db = 0.05 + rng.f64() * 0.3;
    let seed = rng.next_u64();
    (uniform(m, k, da, seed), uniform(k, n, db, seed ^ 0x5A4D))
}

/// The acceptance property: every registered kernel, every shard count,
/// bit-identical to 1-shard and to the unsharded kernel.
#[test]
fn sharded_output_is_bit_identical_for_every_registered_kernel() {
    let registry = registry();
    assert!(registry.len() >= 8, "registry too small: {registry:?}");
    assert!(
        registry.resolve(FormatKind::Csr, Algorithm::GustavsonFast).is_some(),
        "the fast Gustavson kernel must ride this suite: {registry:?}"
    );
    check(0x5AAD, 10, gen_pair, |(a, b)| {
        for kernel in registry.kernels() {
            let name = kernel.name();
            let prepared = kernel
                .prepare(b)
                .map_err(|e| format!("{name} prepare failed: {e}"))?;
            let unsharded = kernel
                .execute(a, &prepared)
                .map_err(|e| format!("{name} unsharded failed: {e}"))?;
            let want = bits(&unsharded.c);
            let mut one_shard: Option<Vec<u32>> = None;
            for shards in SHARD_COUNTS {
                let cfg = ShardConfig { shards, block: BLOCK };
                let out = shard::execute(kernel.as_ref(), a, Some(b), &prepared, cfg)
                    .map_err(|e| format!("{name} @ {shards} shards failed: {e}"))?;
                let got = bits(&out.c);
                if got != want {
                    return Err(format!(
                        "{name} @ {shards} shards diverges bitwise from unsharded \
                         on {:?}×{:?}",
                        a.shape(),
                        b.shape()
                    ));
                }
                match &one_shard {
                    None => one_shard = Some(got),
                    Some(first) => {
                        if &got != first {
                            return Err(format!(
                                "{name} @ {shards} shards diverges from 1-shard"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// A matrix with a completely empty row band (rows 16..32 of 64) shards
/// bit-identically — the empty band yields zero rows, not a skew.
#[test]
fn empty_row_band_edge_case() {
    let mut entries = Vec::new();
    let mut rng = Rng::new(42);
    for i in 0..64u32 {
        if (16..32).contains(&i) {
            continue; // the dead band
        }
        for j in 0..48u32 {
            if rng.f64() < 0.2 {
                entries.push((i, j, rng.f32() + 0.25));
            }
        }
    }
    let a = Csr::from_coo(&Coo::new(64, 48, entries));
    let b = uniform(48, 40, 0.2, 7);
    for kernel in registry().kernels() {
        let prepared = kernel.prepare(&b).unwrap();
        let want = bits(&kernel.execute(&a, &prepared).unwrap().c);
        for shards in [2usize, 4, 8] {
            let out = shard::execute(
                kernel.as_ref(),
                &a,
                Some(&b),
                &prepared,
                ShardConfig { shards, block: BLOCK },
            )
            .unwrap();
            assert_eq!(
                bits(&out.c),
                want,
                "{} with empty band @ {shards} shards",
                kernel.name()
            );
        }
    }
}

/// More shards than rows (and than block rows): the planner caps at the
/// available block rows and the output is still exact.
#[test]
fn shards_exceeding_rows_edge_case() {
    let a = uniform(3, 20, 0.5, 1);
    let b = uniform(20, 10, 0.4, 2);
    for kernel in registry().kernels() {
        let prepared = kernel.prepare(&b).unwrap();
        let want = bits(&kernel.execute(&a, &prepared).unwrap().c);
        let out = shard::execute(
            kernel.as_ref(),
            &a,
            Some(&b),
            &prepared,
            ShardConfig { shards: 8, block: BLOCK },
        )
        .unwrap();
        assert_eq!(bits(&out.c), want, "{}", kernel.name());
        assert_eq!(out.shards.len(), 1, "3 rows = 1 block row = 1 band");
    }
}

/// Planner invariants on random inputs: bands are contiguous, block-
/// aligned, cover every row exactly once, and never exceed the request.
#[test]
fn planner_invariants_hold_on_random_inputs() {
    check(0x81A2, 40, gen_pair, |(a, b)| {
        for shards in SHARD_COUNTS {
            let plan = ShardPlanner::plan(a, Some(b), ShardConfig { shards, block: BLOCK });
            if a.rows() == 0 {
                continue;
            }
            if plan.bands.is_empty() {
                return Err(format!("no bands for {} rows", a.rows()));
            }
            if plan.bands.len() > shards {
                return Err(format!(
                    "{} bands exceed {shards} requested",
                    plan.bands.len()
                ));
            }
            if plan.bands[0].rows.0 != 0
                || plan.bands.last().unwrap().rows.1 != a.rows()
            {
                return Err("bands do not cover the row range".into());
            }
            for w in plan.bands.windows(2) {
                if w[0].rows.1 != w[1].rows.0 {
                    return Err("bands are not contiguous".into());
                }
            }
            for band in &plan.bands {
                if band.rows.0 % BLOCK != 0 {
                    return Err(format!("band start {} unaligned", band.rows.0));
                }
                if band.rows.1 <= band.rows.0 {
                    return Err("empty band".into());
                }
            }
        }
        Ok(())
    });
}

/// The registry-wrapper path: `ShardedKernel` replaces its inner kernel's
/// key and every resolution through the registry is bit-identical.
#[test]
fn sharded_wrapper_behind_the_registry_is_bit_identical() {
    check(0xC0DE, 8, gen_pair, |(a, b)| {
        let mut reg = registry();
        let keys = reg.keys();
        for key in keys {
            let inner = reg.resolve(key.0, key.1).unwrap();
            let want = bits(
                &inner
                    .run(a, b)
                    .map_err(|e| format!("{} inner failed: {e}", inner.name()))?
                    .c,
            );
            reg.register(Arc::new(ShardedKernel::wrap(
                Arc::clone(&inner),
                ShardConfig { shards: 3, block: BLOCK },
            )));
            let wrapped = reg.resolve(key.0, key.1).unwrap();
            if wrapped.name() != "sharded" {
                return Err(format!("{key:?} did not re-resolve to the wrapper"));
            }
            let got = bits(
                &wrapped
                    .run(a, b)
                    .map_err(|e| format!("wrapped {key:?} failed: {e}"))?
                    .c,
            );
            if got != want {
                return Err(format!("wrapped {key:?} diverges bitwise"));
            }
            reg.register(inner); // restore for the next key
        }
        Ok(())
    });
}

/// Work conservation: bands partition the work exactly for kernels whose
/// unit counts are row-decomposable (tiled tile pairs, Gustavson MACs).
#[test]
fn shard_stats_conserve_work_counts() {
    let reg = registry();
    let a = uniform(96, 64, 0.15, 31);
    let b = uniform(64, 52, 0.15, 32);
    for key in [
        (spmm_accel::formats::traits::FormatKind::Csr, Algorithm::Tiled),
        (spmm_accel::formats::traits::FormatKind::Csr, Algorithm::Gustavson),
    ] {
        let kernel = reg.resolve(key.0, key.1).unwrap();
        let prepared = kernel.prepare(&b).unwrap();
        let whole = kernel.execute(&a, &prepared).unwrap();
        let out = shard::execute(
            kernel.as_ref(),
            &a,
            Some(&b),
            &prepared,
            ShardConfig { shards: 4, block: BLOCK },
        )
        .unwrap();
        assert_eq!(
            out.stats.real_pairs,
            whole.stats.real_pairs,
            "{:?} loses or duplicates work",
            key
        );
        let per_band: u64 = out.shards.iter().map(|s| s.stats.real_pairs).sum();
        assert_eq!(per_band, out.stats.real_pairs);
    }
}
