//! Integration: the Fig-3 pipeline (formats -> address streams -> cache
//! hierarchy) on scaled registry datasets, plus hierarchy ablations.

use spmm_accel::cachesim::config::HierarchyConfig;
use spmm_accel::cachesim::runner::{compare, run_crs};
use spmm_accel::cachesim::Hierarchy;
use spmm_accel::datasets::spec::table2_by_name;
use spmm_accel::datasets::synth::{generate, uniform};
use spmm_accel::formats::incrs::InCrsParams;
use spmm_accel::formats::traits::{AccessSink, Site};

#[test]
fn docword_slice_reproduces_fig3_direction() {
    let mut spec = table2_by_name("docword").unwrap();
    spec.rows = 80;
    let m = generate(&spec, 21);
    let cmp = compare(
        &m,
        InCrsParams::default(),
        HierarchyConfig::default(),
        Some(300),
    )
    .unwrap();
    // InCRS reduces accesses AND total time; CRS has the better hit *rate*
    // (long sequential scans) but far more accesses — the paper's story.
    assert!(cmp.l1_access_ratio() > 10.0, "{}", cmp.l1_access_ratio());
    assert!(cmp.total_time_ratio() > 2.0, "{}", cmp.total_time_ratio());
    assert!(
        cmp.crs.stats.l1_hit_rate() > cmp.incrs.stats.l1_hit_rate() * 0.8,
        "CRS scans should be cache-friendly: {} vs {}",
        cmp.crs.stats.l1_hit_rate(),
        cmp.incrs.stats.l1_hit_rate()
    );
}

#[test]
fn prefetcher_helps_crs_scans() {
    let m = uniform(60, 2048, 0.08, 3);
    let with = run_crs(&m, HierarchyConfig::default(), Some(256));
    let without = run_crs(&m, HierarchyConfig::default().no_prefetch(), Some(256));
    assert!(
        with.stats.mem_cycles < without.stats.mem_cycles,
        "prefetch {} !< no-prefetch {}",
        with.stats.mem_cycles,
        without.stats.mem_cycles
    );
    assert!(with.stats.prefetch_useful > 0);
}

#[test]
fn working_set_larger_than_l2_misses() {
    // touch 4 MiB of distinct lines: far beyond the 1 MiB L2
    let mut h = Hierarchy::new(HierarchyConfig::default().no_prefetch());
    for pass in 0..2 {
        for i in 0..65_536u64 {
            h.touch(i * 64, Site::Idx);
        }
        let s = h.stats();
        if pass == 1 {
            // second pass still misses (capacity): L2 can hold only 1/4
            assert!(
                s.l2_misses as f64 > 0.5 * s.l2_accesses as f64,
                "unexpected L2 reuse: {s:?}"
            );
        }
    }
}

#[test]
fn small_working_set_hits_after_warmup() {
    let mut h = Hierarchy::new(HierarchyConfig::default().no_prefetch());
    // 16 KiB working set fits L1 (32 KiB)
    for _ in 0..4 {
        for i in 0..256u64 {
            h.touch(0x100000 + i * 64, Site::Val);
        }
    }
    let s = h.stats();
    assert!(s.l1_hit_rate() > 0.7, "hit rate {}", s.l1_hit_rate());
}

#[test]
fn stats_invariants_hold_under_random_traffic() {
    let mut h = Hierarchy::new(HierarchyConfig::default());
    let mut rng = spmm_accel::util::rng::Rng::new(77);
    for _ in 0..200_000 {
        let site = if rng.bool(0.5) { Site::Idx } else { Site::Val };
        h.touch(rng.below(1 << 28), site);
    }
    let s = h.stats();
    assert!(s.consistent(), "{s:?}");
    assert_eq!(s.l1_accesses, 200_000);
    // mem time must be at least hit-latency * accesses
    assert!(s.mem_cycles >= 2 * s.l1_accesses);
}

#[test]
fn memory_latency_knob_scales_time() {
    let m = uniform(40, 1024, 0.1, 5);
    let fast = run_crs(
        &m,
        HierarchyConfig {
            mem_latency: 50,
            ..HierarchyConfig::default()
        },
        Some(128),
    );
    let slow = run_crs(
        &m,
        HierarchyConfig {
            mem_latency: 400,
            ..HierarchyConfig::default()
        },
        Some(128),
    );
    assert!(slow.stats.mem_cycles > fast.stats.mem_cycles);
    assert_eq!(slow.stats.l1_accesses, fast.stats.l1_accesses);
}

#[test]
fn incrs_beats_csr_even_without_prefetching() {
    // ablation: the InCRS win is structural, not a prefetcher artifact
    let m = uniform(50, 2048, 0.06, 9);
    let cfg = HierarchyConfig::default().no_prefetch();
    let cmp = compare(&m, InCrsParams::default(), cfg, Some(256)).unwrap();
    assert!(cmp.total_time_ratio() > 2.0, "{}", cmp.total_time_ratio());
}
