//! Architecture-simulator throughput: the Fig-4/5 sweep machinery must
//! finish Table-IV-scale datasets in seconds (DESIGN.md §6 target: the full
//! fig5 sweep < 30 s).

use spmm_accel::arch::{
    fpic_simulate, sync_cycle_model, sync_multiply, FpicConfig, SyncMeshConfig,
};
use spmm_accel::datasets::spec::by_name;
use spmm_accel::datasets::synth::{generate, uniform};
use spmm_accel::formats::traits::SparseMatrix;
use spmm_accel::util::bench::{bench, black_box, report};

fn main() {
    println!("== bench_arch ==");

    // stream-level cycle model on a mid-size dataset (A x Aᵀ)
    let mks = {
        let mut s = by_name("mks").unwrap();
        s.rows = 2_000;
        s.cols = 2_000;
        generate(&s, 3)
    };
    let r = bench(1, 5, || {
        black_box(sync_cycle_model(&mks, &mks, SyncMeshConfig::default()).cycles);
    });
    report("sync/cycle_model(mks 2k)", r, mks.nnz() as f64, "nnz");

    // FPIC MaxNode sweep on the same dataset
    let r = bench(1, 5, || {
        black_box(fpic_simulate(&mks, &mks, FpicConfig { units: 8, ..FpicConfig::default() }).0.cycles);
    });
    report("fpic/maxnode(mks 2k)", r, mks.nnz() as f64, "nnz");

    // full-size sch (banded 20k) through the sync cycle model — the
    // heaviest single fig5 cell
    let sch = generate(&by_name("sch").unwrap(), 3);
    let r = bench(0, 3, || {
        black_box(sync_cycle_model(&sch, &sch, SyncMeshConfig::default()).cycles);
    });
    report("sync/cycle_model(sch 20k)", r, sch.nnz() as f64, "nnz");
    let r = bench(0, 3, || {
        black_box(
            fpic_simulate(&sch, &sch, FpicConfig { units: 8, ..FpicConfig::default() })
                .0
                .cycles,
        );
    });
    report("fpic/maxnode(sch 20k)", r, sch.nnz() as f64, "nnz");

    // node-level functional sim (small — used by tests/validation);
    // A×Aᵀ so the second operand is A itself (rows of Bᵀ = rows of A)
    let small = uniform(32, 128, 0.15, 4);
    let r = bench(1, 5, || {
        black_box(
            sync_multiply(&small, &small, SyncMeshConfig { mesh: 8, round: 32 })
                .1
                .cycles,
        );
    });
    report("sync/functional(32x128, mesh 8)", r, small.nnz() as f64, "nnz");
}
