//! Cache-simulator throughput — the L3 §Perf hot path. Target (DESIGN.md
//! §6): ≥ 100 M simulated accesses/s on the demand path.

use spmm_accel::cachesim::{Hierarchy, HierarchyConfig};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::formats::incrs::InCrsParams;
use spmm_accel::formats::traits::{AccessSink, Site};
use spmm_accel::util::bench::{bench, black_box, report};
use spmm_accel::util::rng::Rng;

fn main() {
    println!("== bench_cachesim ==");

    // raw demand-access throughput: sequential (hits) and random (misses)
    let n = 2_000_000u64;
    let r = bench(1, 5, || {
        let mut h = Hierarchy::new(HierarchyConfig::default());
        for i in 0..n {
            h.touch(0x100000 + (i % 8192) * 4, Site::Idx);
        }
        black_box(h.stats().l1_hits);
    });
    report("hierarchy/sequential_hot", r, n as f64, "accesses");

    let mut rng = Rng::new(5);
    let addrs: Vec<u64> = (0..n).map(|_| rng.below(1 << 30)).collect();
    let r = bench(1, 5, || {
        let mut h = Hierarchy::new(HierarchyConfig::default());
        for &a in &addrs {
            h.touch(a, Site::Val);
        }
        black_box(h.stats().mem_cycles);
    });
    report("hierarchy/random_cold", r, n as f64, "accesses");

    // no-prefetch ablation
    let r = bench(1, 5, || {
        let mut h = Hierarchy::new(HierarchyConfig::default().no_prefetch());
        for i in 0..n {
            h.touch(0x100000 + i * 4, Site::Idx);
        }
        black_box(h.stats().l1_hits);
    });
    report("hierarchy/sequential_nopf", r, n as f64, "accesses");

    // the Fig-3 inner loop end to end (format locate -> hierarchy)
    let m = uniform(200, 8192, 0.05, 9);
    let r = bench(0, 3, || {
        let run = spmm_accel::cachesim::run_crs(&m, HierarchyConfig::default(), Some(256));
        black_box(run.stats.l1_accesses);
    });
    // items = L1 accesses of one run (measure once for the count)
    let once = spmm_accel::cachesim::run_crs(&m, HierarchyConfig::default(), Some(256));
    report(
        "fig3/crs_column_read(256 cols)",
        r,
        once.stats.l1_accesses as f64,
        "accesses",
    );
    let incrs_run = spmm_accel::cachesim::run_incrs(
        &m,
        InCrsParams::default(),
        HierarchyConfig::default(),
        Some(256),
    )
    .unwrap();
    let r = bench(0, 3, || {
        let run = spmm_accel::cachesim::run_incrs(
            &m,
            InCrsParams::default(),
            HierarchyConfig::default(),
            Some(256),
        )
        .unwrap();
        black_box(run.stats.l1_accesses);
    });
    report(
        "fig3/incrs_column_read(256 cols)",
        r,
        incrs_run.stats.l1_accesses as f64,
        "accesses",
    );
}
