//! End-to-end numeric-path benchmarks through the unified engine: plan
//! construction, registered-kernel execution, serial-vs-parallel tiled
//! execution on the synthetic 4096² dataset, a 1/2/4/8-shard row-band
//! sweep, a scalar-vs-fast Gustavson thread sweep (bit-checked, with
//! workspace-pool reuse measured through a coalesced served batch), a
//! native-format ingestion sweep (conversion cost included), and served
//! throughput through the coordinator. Writes machine-readable summaries
//! to `BENCH_engine.json` (override with `SPMM_BENCH_OUT`),
//! `BENCH_shard.json` (`SPMM_BENCH_SHARD_OUT`), `BENCH_gustavson.json`
//! (`SPMM_BENCH_GUSTAVSON_OUT`), `BENCH_format.json`
//! (`SPMM_BENCH_FORMAT_OUT`), and a hyper-sparse power-law
//! scalar-vs-outer sweep in `BENCH_outer.json` (`SPMM_BENCH_OUTER_OUT`).

use std::sync::Arc;

use spmm_accel::coordinator::{JobHandle, KernelSpec, Server, ServerConfig};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::datasets::{generate, ColumnDist, DatasetSpec, NnzRow};
use spmm_accel::engine::{
    shard, tiled, Algorithm, GustavsonFastKernel, GustavsonKernel, PreparedB, Registry,
    ShardConfig, SpmmKernel, TiledConfig, TiledKernel,
};
use spmm_accel::formats::traits::FormatKind;
use spmm_accel::formats::MatrixOperand;
use spmm_accel::runtime::{Manifest, NumericEngine};
use spmm_accel::spmm::plan::{plan, Geometry};
use spmm_accel::util::bench::{bench, black_box, report};
use spmm_accel::util::json::{obj, Json};

fn main() {
    println!("== bench_e2e ==");
    let a = uniform(256, 512, 0.06, 1);
    let b = uniform(512, 256, 0.06, 2);
    let geom = Geometry::default();

    // planning (block pair matching + chunking)
    let r = bench(1, 5, || {
        black_box(plan(&a, &b, geom).total_pairs);
    });
    let p = plan(&a, &b, geom);
    report("plan/build(256x512x256)", r, p.total_pairs as f64, "pairs");
    let macs = p.total_pairs as f64 * (32.0 * 32.0 * 32.0);

    // every registered kernel on the medium workload (skip the oracle)
    let reg = Registry::with_default_kernels(geom, 4);
    for k in reg.kernels() {
        if k.algorithm() == spmm_accel::engine::Algorithm::Dense {
            continue;
        }
        let r = bench(1, 3, || {
            black_box(k.run(&a, &b).unwrap().stats.real_pairs);
        });
        report(
            &format!("exec/{}_{}", k.algorithm().name(), k.name()),
            r,
            macs,
            "MACs",
        );
    }

    // PJRT backend execution (AOT Pallas kernel), if artifacts exist
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        match NumericEngine::pjrt(&dir) {
            Ok(pjrt) => {
                let r = bench(1, 3, || {
                    black_box(pjrt.spmm(&a, &b).unwrap().1.real_pairs);
                });
                report("exec/pjrt_backend", r, macs, "MACs");
            }
            Err(e) => println!("exec/pjrt_backend: skipped ({e})"),
        }
    } else {
        println!("exec/pjrt_backend: skipped (run `make artifacts`)");
    }

    // serial vs parallel tiled executor on the synthetic 4096² dataset
    let big_a = uniform(4096, 4096, 0.001, 11);
    let big_b = uniform(4096, 4096, 0.001, 12);
    let serial_cfg = TiledConfig { block: 32, workers: 1 };
    let par_workers = 4usize;
    let par_cfg = TiledConfig { block: 32, workers: par_workers };

    let r_serial = bench(1, 3, || {
        black_box(tiled::execute(&big_a, &big_b, serial_cfg).unwrap().1.real_pairs);
    });
    let (c_serial, stats) = tiled::execute(&big_a, &big_b, serial_cfg).unwrap();
    let big_macs = stats.real_pairs as f64 * (32.0 * 32.0 * 32.0);
    report("tiled/serial(4096x4096 @ 0.1%)", r_serial, big_macs, "MACs");

    let r_par = bench(1, 3, || {
        black_box(tiled::execute(&big_a, &big_b, par_cfg).unwrap().1.real_pairs);
    });
    let (c_par, par_stats) = tiled::execute(&big_a, &big_b, par_cfg).unwrap();
    report(
        &format!("tiled/parallel_{par_workers}w(4096x4096 @ 0.1%)"),
        r_par,
        big_macs,
        "MACs",
    );

    let bit_identical = c_serial.data == c_par.data;
    let speedup = r_serial.median.as_secs_f64() / r_par.median.as_secs_f64();
    println!(
        "tiled 4096²: {} tile pairs, serial {:?} vs {}w {:?} -> speedup {speedup:.2}x, \
         bit-identical: {bit_identical}",
        stats.real_pairs, r_serial.median, par_stats.threads, r_par.median
    );

    // sharded row-band sweep on the same 4096² dataset: the tiled kernel
    // (1 internal worker, so shard workers are the only parallelism axis)
    // at 1/2/4/8 shards, bit-checked against the 1-shard run
    let shard_kernel = TiledKernel::new(TiledConfig { block: 32, workers: 1 });
    let shard_prepared = shard_kernel.prepare(&big_b).unwrap();
    let mut shard_sweep: Vec<Json> = Vec::new();
    let mut one_shard_bits: Option<Vec<u32>> = None;
    let mut one_shard_ms = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let cfg = ShardConfig { shards, block: 32 };
        let r = bench(1, 3, || {
            black_box(
                shard::execute(&shard_kernel, &big_a, Some(&big_b), &shard_prepared, cfg)
                    .unwrap()
                    .stats
                    .real_pairs,
            );
        });
        let out =
            shard::execute(&shard_kernel, &big_a, Some(&big_b), &shard_prepared, cfg).unwrap();
        let bits = out.c.bit_pattern();
        let bit_identical = match &one_shard_bits {
            None => {
                one_shard_bits = Some(bits);
                one_shard_ms = r.median.as_secs_f64() * 1e3;
                true
            }
            Some(base) => base == &bits,
        };
        let ms = r.median.as_secs_f64() * 1e3;
        report(
            &format!("shard/{}x(4096x4096 @ 0.1%)", shards),
            r,
            big_macs,
            "MACs",
        );
        println!(
            "shard sweep {shards}: {} bands, {:.1}ms, speedup {:.2}x, bit-identical: {bit_identical}",
            out.shards.len(),
            ms,
            one_shard_ms / ms
        );
        shard_sweep.push(obj([
            ("shards", Json::from(shards)),
            ("bands", Json::from(out.shards.len())),
            ("median_ms", Json::from(ms)),
            ("speedup_vs_1", Json::from(one_shard_ms / ms)),
            ("tile_pairs", Json::from(out.stats.real_pairs)),
            ("bit_identical_to_1_shard", Json::Bool(bit_identical)),
        ]));
    }
    let shard_out_path =
        std::env::var("SPMM_BENCH_SHARD_OUT").unwrap_or_else(|_| "BENCH_shard.json".into());
    let shard_summary = obj([
        ("bench", Json::from("bench_e2e/shard")),
        (
            "dataset",
            Json::from("uniform 4096x4096, density 0.001, seeds 11/12"),
        ),
        ("kernel", Json::from("tiled (1 internal worker)")),
        ("block", Json::from(32usize)),
        ("sweep", Json::Arr(shard_sweep)),
    ]);
    match std::fs::write(&shard_out_path, shard_summary.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {shard_out_path}"),
        Err(e) => println!("could not write {shard_out_path}: {e}"),
    }

    // scalar vs fast Gustavson on 4096²: the vectorized, workspace-pooled
    // backend at 1/2/4/8 A-row-band threads, bit-checked against the
    // scalar kernel per configuration
    let ga = uniform(4096, 4096, 0.005, 31);
    let gb = Arc::new(uniform(4096, 4096, 0.005, 32));
    let scalar_kernel = GustavsonKernel;
    let scalar_prepared = scalar_kernel.prepare_shared(&gb).unwrap();
    let r_scalar = bench(1, 3, || {
        black_box(
            scalar_kernel
                .execute(&ga, &scalar_prepared)
                .unwrap()
                .stats
                .real_pairs,
        );
    });
    let scalar_out = scalar_kernel.execute(&ga, &scalar_prepared).unwrap();
    let g_macs = scalar_out.stats.real_pairs as f64;
    let scalar_bits = scalar_out.c.bit_pattern();
    let scalar_ms = r_scalar.median.as_secs_f64() * 1e3;
    report("gustavson/scalar(4096x4096 @ 0.5%)", r_scalar, g_macs, "MACs");
    let mut gust_sweep: Vec<Json> = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        let k = GustavsonFastKernel::new(threads);
        let prepared = k.prepare_shared(&gb).unwrap();
        let r = bench(1, 3, || {
            black_box(k.execute(&ga, &prepared).unwrap().stats.real_pairs);
        });
        let out = k.execute(&ga, &prepared).unwrap();
        let bit_identical = out.c.bit_pattern() == scalar_bits;
        let (pool_hits, pool_misses) = match &prepared {
            PreparedB::Pooled(pb) => (pb.pool.hits(), pb.pool.misses()),
            _ => (0, 0),
        };
        let ms = r.median.as_secs_f64() * 1e3;
        report(
            &format!("gustavson/fast_{threads}t(4096x4096 @ 0.5%)"),
            r,
            g_macs,
            "MACs",
        );
        println!(
            "gustavson sweep {threads}t: {:.2}ms vs scalar {scalar_ms:.2}ms -> speedup \
             {:.2}x, pool {pool_hits} hits / {pool_misses} misses, bit-identical: \
             {bit_identical}",
            ms,
            scalar_ms / ms
        );
        gust_sweep.push(obj([
            ("threads", Json::from(threads)),
            ("median_ms", Json::from(ms)),
            ("scalar_ms", Json::from(scalar_ms)),
            ("speedup_vs_scalar", Json::from(scalar_ms / ms)),
            ("macs", Json::from(out.stats.real_pairs)),
            ("pool_hits", Json::from(pool_hits)),
            ("pool_misses", Json::from(pool_misses)),
            ("bit_identical_to_scalar", Json::Bool(bit_identical)),
        ]));
    }
    // workspace-pool reuse across a coalesced served micro-batch: one
    // worker, 16 jobs sharing B — the first allocates, the rest reuse
    let pool_server = Server::start(ServerConfig {
        workers: 1,
        queue_depth: 32,
        kernel: KernelSpec::Fixed(FormatKind::Csr, Algorithm::GustavsonFast),
        geometry: geom,
        ..Default::default()
    });
    let pool_client = pool_server.client();
    let pa = Arc::new(uniform(1024, 1024, 0.01, 33));
    let pb = Arc::new(uniform(1024, 1024, 0.01, 34));
    let handles = pool_client.submit_many((0..16u64).map(|i| {
        pool_client.job(pa.clone(), pb.clone()).id(i).keep_result(false).build()
    }));
    for res in JobHandle::batch_wait_all(handles) {
        black_box(res.unwrap().report.real_pairs);
    }
    let pool_snap = pool_client.metrics();
    println!(
        "served coalesced batch: {} jobs, {} PreparedB builds, workspace pool \
         {} hits / {} misses, {} kernel observations",
        pool_snap.jobs_completed,
        pool_snap.prepare_builds,
        pool_snap.workspace_pool_hits,
        pool_snap.workspace_pool_misses,
        pool_snap.kernel_observations
    );
    drop(pool_client);
    pool_server.shutdown();
    let gustavson_out_path = std::env::var("SPMM_BENCH_GUSTAVSON_OUT")
        .unwrap_or_else(|_| "BENCH_gustavson.json".into());
    let gustavson_summary = obj([
        ("bench", Json::from("bench_e2e/gustavson")),
        (
            "dataset",
            Json::from("uniform 4096x4096, density 0.005, seeds 31/32"),
        ),
        ("scalar_ms", Json::from(scalar_ms)),
        ("sweep", Json::Arr(gust_sweep)),
        (
            "served_coalesced_batch",
            obj([
                ("jobs", Json::from(pool_snap.jobs_completed)),
                ("prepare_builds", Json::from(pool_snap.prepare_builds)),
                ("workspace_pool_hits", Json::from(pool_snap.workspace_pool_hits)),
                (
                    "workspace_pool_misses",
                    Json::from(pool_snap.workspace_pool_misses),
                ),
                (
                    "kernel_observations",
                    Json::from(pool_snap.kernel_observations),
                ),
            ]),
        ),
    ]);
    match std::fs::write(&gustavson_out_path, gustavson_summary.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {gustavson_out_path}"),
        Err(e) => println!("could not write {gustavson_out_path}: {e}"),
    }

    // native-format ingestion sweep: the same multiply with operands
    // arriving in Coo / InCRS / CSR, conversion cost included — the
    // ingestion path (MatrixOperand::to_csr) + prepare + execute, measured
    // end to end on the tiled kernel
    let fa = Arc::new(uniform(1024, 1024, 0.01, 21));
    let fb = Arc::new(uniform(1024, 1024, 0.01, 22));
    let ingest_kernel = TiledKernel::new(TiledConfig { block: 32, workers: 4 });
    let mut format_sweep: Vec<Json> = Vec::new();
    let mut csr_bits: Option<Vec<u32>> = None;
    for kind in [FormatKind::Csr, FormatKind::Coo, FormatKind::InCrs] {
        let a_native = MatrixOperand::from(Arc::clone(&fa)).convert(kind).unwrap();
        let b_native = MatrixOperand::from(Arc::clone(&fb)).convert(kind).unwrap();
        let r = bench(1, 3, || {
            let a_csr = a_native.to_csr().unwrap();
            let b_csr = b_native.to_csr().unwrap();
            let prepared = ingest_kernel.prepare_shared(&b_csr).unwrap();
            black_box(
                ingest_kernel
                    .execute(&a_csr, &prepared)
                    .unwrap()
                    .stats
                    .real_pairs,
            );
        });
        let out = ingest_kernel
            .execute(
                &a_native.to_csr().unwrap(),
                &ingest_kernel
                    .prepare_shared(&b_native.to_csr().unwrap())
                    .unwrap(),
            )
            .unwrap();
        let bits = out.c.bit_pattern();
        let bit_identical = match &csr_bits {
            None => {
                csr_bits = Some(bits);
                true
            }
            Some(base) => base == &bits,
        };
        let ms = r.median.as_secs_f64() * 1e3;
        report(
            &format!("ingest/{}(1024x1024 @ 1%)", kind.name()),
            r,
            out.stats.real_pairs as f64 * (32.0 * 32.0 * 32.0),
            "MACs",
        );
        println!(
            "ingest sweep {}: {:.1}ms (conversion ~{:.0}+{:.0} words), bit-identical: {bit_identical}",
            kind.name(),
            ms,
            a_native.conversion_words(),
            b_native.conversion_words(),
        );
        format_sweep.push(obj([
            ("format", Json::from(kind.name())),
            ("median_ms", Json::from(ms)),
            (
                "conversion_words",
                Json::from(a_native.conversion_words() + b_native.conversion_words()),
            ),
            ("tile_pairs", Json::from(out.stats.real_pairs)),
            ("bit_identical_to_csr", Json::Bool(bit_identical)),
        ]));
    }
    let format_out_path = std::env::var("SPMM_BENCH_FORMAT_OUT")
        .unwrap_or_else(|_| "BENCH_format.json".into());
    let format_summary = obj([
        ("bench", Json::from("bench_e2e/format")),
        (
            "dataset",
            Json::from("uniform 1024x1024, density 0.01, seeds 21/22"),
        ),
        ("kernel", Json::from("tiled (4 workers, block 32)")),
        ("sweep", Json::Arr(format_sweep)),
    ]);
    match std::fs::write(&format_out_path, format_summary.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {format_out_path}"),
        Err(e) => println!("could not write {format_out_path}: {e}"),
    }

    // hyper-sparse power-law sweep: ~4096² with a handful of non-zeros per
    // row under Zipf column popularity — the regime the outer-product
    // backend targets. Every row-centric kernel plus outer, prepare-once,
    // bit-checked against the scalar Gustavson baseline.
    let zipf = |rows: usize, cols: usize, seed: u64| {
        generate(
            &DatasetSpec {
                name: "bench-outer-zipf",
                rows,
                cols,
                stated_density: 4.0 / cols as f64,
                nnz_row: NnzRow { min: 0, avg: 4.0, max: 64 },
                dist: ColumnDist::Zipf(1.2),
            },
            seed,
        )
    };
    let ha = zipf(4096, 4096, 61);
    let hb = Arc::new(zipf(4096, 4096, 62));
    let h_scalar = reg
        .resolve(FormatKind::Csr, Algorithm::Gustavson)
        .expect("scalar gustavson registered");
    let h_prepared = h_scalar.prepare_shared(&hb).unwrap();
    let h_bits = h_scalar.execute(&ha, &h_prepared).unwrap().c.bit_pattern();
    let mut outer_sweep: Vec<Json> = Vec::new();
    let mut scalar_hs_ms = 0.0f64;
    let mut outer_hs_ms = 0.0f64;
    let mut row_centric_best_ms = f64::INFINITY;
    for (fmt, alg) in [
        (FormatKind::Csr, Algorithm::Gustavson),
        (FormatKind::Csr, Algorithm::GustavsonFast),
        (FormatKind::Csr, Algorithm::Inner),
        (FormatKind::Csr, Algorithm::Tiled),
        (FormatKind::Csc, Algorithm::OuterProduct),
    ] {
        let k = reg.resolve(fmt, alg).expect("sweep kernel registered");
        let prepared = k.prepare_shared(&hb).unwrap();
        let r = bench(1, 3, || {
            black_box(k.execute(&ha, &prepared).unwrap().stats.real_pairs);
        });
        let out = k.execute(&ha, &prepared).unwrap();
        let bit_identical = out.c.bit_pattern() == h_bits;
        let ms = r.median.as_secs_f64() * 1e3;
        match alg {
            Algorithm::Gustavson => scalar_hs_ms = ms,
            Algorithm::OuterProduct => outer_hs_ms = ms,
            _ => {}
        }
        if alg != Algorithm::OuterProduct {
            row_centric_best_ms = row_centric_best_ms.min(ms);
        }
        report(
            &format!("outer/{}(4096x4096 zipf)", k.name()),
            r,
            out.stats.real_pairs as f64,
            "MACs",
        );
        println!(
            "hyper-sparse sweep {}: {ms:.2}ms, bit-identical to scalar: {bit_identical}",
            k.name()
        );
        outer_sweep.push(obj([
            ("kernel", Json::from(k.name())),
            ("format", Json::from(fmt.name())),
            ("algorithm", Json::from(alg.name())),
            ("median_ms", Json::from(ms)),
            ("macs", Json::from(out.stats.real_pairs)),
            ("bit_identical_to_scalar", Json::Bool(bit_identical)),
        ]));
    }
    println!(
        "hyper-sparse 4096² zipf: outer {outer_hs_ms:.2}ms vs scalar {scalar_hs_ms:.2}ms \
         ({:.2}x) vs best row-centric {row_centric_best_ms:.2}ms ({:.2}x)",
        scalar_hs_ms / outer_hs_ms,
        row_centric_best_ms / outer_hs_ms
    );
    let outer_out_path =
        std::env::var("SPMM_BENCH_OUTER_OUT").unwrap_or_else(|_| "BENCH_outer.json".into());
    let outer_summary = obj([
        ("bench", Json::from("bench_e2e/outer")),
        (
            "dataset",
            Json::from("zipf(1.2) 4096x4096, ~4 nnz/row, seeds 61/62"),
        ),
        ("sweep", Json::Arr(outer_sweep)),
        ("scalar_ms", Json::from(scalar_hs_ms)),
        ("outer_ms", Json::from(outer_hs_ms)),
        ("best_row_centric_ms", Json::from(row_centric_best_ms)),
        (
            "outer_speedup_vs_scalar",
            Json::from(scalar_hs_ms / outer_hs_ms),
        ),
        (
            "outer_speedup_vs_best_row_centric",
            Json::from(row_centric_best_ms / outer_hs_ms),
        ),
    ]);
    match std::fs::write(&outer_out_path, outer_summary.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {outer_out_path}"),
        Err(e) => println!("could not write {outer_out_path}: {e}"),
    }

    // served throughput: 16 jobs through 4 CPU workers via the client API
    let r_serve = bench(0, 3, || {
        let server = Server::start(ServerConfig {
            workers: 4,
            queue_depth: 8,
            geometry: geom,
            artifacts_dir: dir.clone(),
            ..Default::default()
        });
        let client = server.client();
        let aj = Arc::new(uniform(128, 128, 0.08, 3));
        let jobs = (0..16u64)
            .map(|i| client.job(aj.clone(), aj.clone()).id(i).keep_result(false).build());
        let handles = client.submit_many(jobs);
        for res in JobHandle::batch_wait_all(handles) {
            black_box(res.unwrap().report.real_pairs);
        }
        drop(client);
        server.shutdown();
    });
    report("serve/16_jobs_4_workers", r_serve, 16.0, "jobs");

    // machine-readable summary
    let out_path = std::env::var("SPMM_BENCH_OUT").unwrap_or_else(|_| "BENCH_engine.json".into());
    let summary = obj([
        ("bench", Json::from("bench_e2e/engine")),
        (
            "dataset",
            Json::from("uniform 4096x4096, density 0.001, seeds 11/12"),
        ),
        ("block", Json::from(32usize)),
        ("tile_pairs", Json::from(stats.real_pairs)),
        ("serial_ms", Json::from(r_serial.median.as_secs_f64() * 1e3)),
        ("parallel_ms", Json::from(r_par.median.as_secs_f64() * 1e3)),
        ("workers", Json::from(par_workers)),
        ("threads_used", Json::from(par_stats.threads)),
        ("speedup", Json::from(speedup)),
        ("bit_identical", Json::Bool(bit_identical)),
        (
            "serve_16_jobs_4_workers_ms",
            Json::from(r_serve.median.as_secs_f64() * 1e3),
        ),
    ]);
    match std::fs::write(&out_path, summary.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => println!("could not write {out_path}: {e}"),
    }
}
