//! End-to-end numeric-path benchmarks: plan construction, CPU vs PJRT
//! dispatch execution, and served throughput through the coordinator.

use std::sync::Arc;

use spmm_accel::coordinator::{
    EngineKind, JobOptions, Server, ServerConfig, SpmmJob,
};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::runtime::{Manifest, NumericEngine};
use spmm_accel::spmm::plan::{plan, Geometry};
use spmm_accel::util::bench::{bench, black_box, report};

fn main() {
    println!("== bench_e2e ==");
    let a = uniform(256, 512, 0.06, 1);
    let b = uniform(512, 256, 0.06, 2);
    let geom = Geometry::default();

    // planning (block pair matching + chunking)
    let r = bench(1, 5, || {
        black_box(plan(&a, &b, geom).total_pairs);
    });
    let p = plan(&a, &b, geom);
    report("plan/build(256x512x256)", r, p.total_pairs as f64, "pairs");

    // CPU backend execution
    let cpu = NumericEngine::cpu(geom);
    let r = bench(1, 3, || {
        black_box(cpu.spmm(&a, &b).unwrap().1.real_pairs);
    });
    let macs = p.total_pairs as f64 * (32.0 * 32.0 * 32.0);
    report("exec/cpu_backend", r, macs, "MACs");

    // PJRT backend execution (AOT Pallas kernel), if artifacts exist
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let pjrt = NumericEngine::pjrt(&dir).expect("pjrt engine");
        let r = bench(1, 3, || {
            black_box(pjrt.spmm(&a, &b).unwrap().1.real_pairs);
        });
        report("exec/pjrt_backend", r, macs, "MACs");
    } else {
        println!("exec/pjrt_backend: skipped (run `make artifacts`)");
    }

    // served throughput: 16 jobs through 4 CPU workers
    let r = bench(0, 3, || {
        let server = Server::start(ServerConfig {
            workers: 4,
            queue_depth: 8,
            engine: EngineKind::Cpu,
            geometry: geom,
            artifacts_dir: dir.clone(),
        });
        let aj = Arc::new(uniform(128, 128, 0.08, 3));
        let rxs: Vec<_> = (0..16u64)
            .map(|i| {
                server.submit(
                    SpmmJob::new(i, aj.clone(), aj.clone())
                        .with_opts(JobOptions { verify: false, keep_result: false }),
                )
            })
            .collect();
        for rx in rxs {
            black_box(rx.recv().unwrap().result.unwrap().report.real_pairs);
        }
        server.shutdown();
    });
    report("serve/16_jobs_4_workers", r, 16.0, "jobs");
}
