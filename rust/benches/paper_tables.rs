//! THE regeneration harness: reruns every paper table and figure and prints
//! the same rows/series the paper reports, with wall-clock per experiment.
//!
//! `cargo bench --bench paper_tables` runs everything at PAPER_SCALE
//! (default 1.0 = full paper workloads; set PAPER_SCALE=0.1 for a quick
//! pass). Output is what EXPERIMENTS.md records.

use std::time::Instant;

use spmm_accel::eval::{run_experiment, ExpOptions, ALL_EXPERIMENTS};

fn main() {
    let scale: f64 = std::env::var("PAPER_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let seed: u64 = std::env::var("PAPER_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);
    let opts = ExpOptions { seed, scale };
    println!("== paper_tables (scale {scale}, seed {seed}) ==\n");
    let t_all = Instant::now();
    for id in ALL_EXPERIMENTS.iter().chain(["table5"].iter()) {
        let t = Instant::now();
        match run_experiment(id, opts) {
            Ok(results) => {
                for r in results {
                    r.print();
                    if let Ok(dir) = std::env::var("PAPER_SAVE") {
                        let _ = r.save(std::path::Path::new(&dir));
                    }
                }
                println!("[{id} done in {:?}]\n", t.elapsed());
            }
            Err(e) => println!("[{id} FAILED: {e}]\n"),
        }
    }
    println!("all experiments done in {:?}", t_all.elapsed());
}
