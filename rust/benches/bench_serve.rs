//! Serve-path throughput benchmark: N jobs sharing one `B` operand pumped
//! through `SpmmClient::submit_many`, with B-sharing micro-batch coalescing
//! on vs off. The prepare-heavy inner-InCRS kernel makes the amortization
//! visible: coalescing builds `PreparedB` once per worker (then the LRU
//! serves it), the uncoalesced path builds it once per job.
//!
//! Writes a machine-readable summary to `BENCH_serve.json` (override the
//! path with `SPMM_BENCH_SERVE_OUT`), plus a learned-selection comparison
//! — auto-selection latency with a serving-trained cost model warm-loaded
//! vs static cost hints — to `BENCH_selection.json` (override with
//! `SPMM_BENCH_SELECTION_OUT`), plus a socket-vs-in-process sharded
//! execution comparison (two loopback shard workers, bit-identity
//! asserted) to `BENCH_transport.json` (override with
//! `SPMM_BENCH_TRANSPORT_OUT`).
//!
//! Run: `cargo bench --bench bench_serve`

use std::net::TcpListener;
use std::sync::Arc;

use spmm_accel::coordinator::{
    AdmissionConfig, CoalesceConfig, JobError, JobHandle, KernelSpec, LearnConfig,
    MetricsSnapshot, Server, ServerConfig,
};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::{remote, shard, Algorithm, Registry, ShardConfig, SocketTransport};
use spmm_accel::formats::csr::Csr;
use spmm_accel::formats::traits::FormatKind;
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::bench::{bench, black_box, report, BenchResult};
use spmm_accel::util::json::{obj, Json};

const JOBS: usize = 32;
const WORKERS: usize = 4;

fn serve_batch(coalesce: bool, a_set: &[Arc<Csr>], b: &Arc<Csr>) -> MetricsSnapshot {
    let server = Server::start(ServerConfig {
        workers: WORKERS,
        queue_depth: 32,
        // inner-product over InCRS: prepare builds the counter vectors,
        // the cost the paper (and the coalescer) amortizes
        kernel: KernelSpec::Fixed(FormatKind::InCrs, Algorithm::Inner),
        geometry: Geometry::default(),
        coalesce: CoalesceConfig { enabled: coalesce, ..Default::default() },
        ..Default::default()
    });
    let client = server.client();
    let jobs = a_set
        .iter()
        .enumerate()
        .map(|(i, a)| {
            client
                .job(Arc::clone(a), Arc::clone(b))
                .id(i as u64)
                .keep_result(false)
                .build()
        })
        .collect::<Vec<_>>();
    let handles = client.submit_many(jobs);
    for res in JobHandle::batch_wait_all(handles) {
        black_box(res.expect("job ok").report.real_pairs);
    }
    let snap = client.metrics();
    drop(client);
    server.shutdown();
    snap
}

fn run_case(coalesce: bool, a_set: &[Arc<Csr>], b: &Arc<Csr>) -> (BenchResult, MetricsSnapshot) {
    let r = bench(1, 3, || {
        black_box(serve_batch(coalesce, a_set, b).jobs_completed);
    });
    let snap = serve_batch(coalesce, a_set, b);
    (r, snap)
}

/// One auto-selected serve run under the given learn config; returns the
/// metrics snapshot (per-job p50/p99) and the batch wall in milliseconds.
fn serve_auto(learn: LearnConfig, a_set: &[Arc<Csr>], b: &Arc<Csr>) -> (MetricsSnapshot, f64) {
    let server = Server::start(ServerConfig {
        workers: WORKERS,
        queue_depth: 32,
        kernel: KernelSpec::Auto,
        geometry: Geometry::default(),
        learn,
        ..Default::default()
    });
    let client = server.client();
    let t0 = std::time::Instant::now();
    let jobs = a_set
        .iter()
        .enumerate()
        .map(|(i, a)| {
            client
                .job(Arc::clone(a), Arc::clone(b))
                .id(i as u64)
                .keep_result(false)
                .build()
        })
        .collect::<Vec<_>>();
    let handles = client.submit_many(jobs);
    for res in JobHandle::batch_wait_all(handles) {
        black_box(res.expect("job ok").report.real_pairs);
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let snap = client.metrics();
    drop(client);
    server.shutdown();
    (snap, wall_ms)
}

fn main() {
    println!("== bench_serve ==");
    // one shared B (docword-ish: wide, moderately dense rows — the InCRS
    // counter build is a real cost), many distinct As; sized so the whole
    // on/off comparison stays a CI-friendly smoke
    let b = Arc::new(uniform(256, 512, 0.05, 99));
    let a_set: Vec<Arc<Csr>> = (0..JOBS as u64)
        .map(|i| Arc::new(uniform(48, 256, 0.08, i)))
        .collect();

    let (r_on, snap_on) = run_case(true, &a_set, &b);
    report(
        &format!("serve/{JOBS}_jobs_shared_b_coalesce_on"),
        r_on,
        JOBS as f64,
        "jobs",
    );
    let (r_off, snap_off) = run_case(false, &a_set, &b);
    report(
        &format!("serve/{JOBS}_jobs_shared_b_coalesce_off"),
        r_off,
        JOBS as f64,
        "jobs",
    );

    let speedup = r_off.median.as_secs_f64() / r_on.median.as_secs_f64();
    println!(
        "coalescing on:  {} PreparedB builds for {} jobs ({} cache hits, {} coalesced)",
        snap_on.prepare_builds, snap_on.jobs_completed, snap_on.prepare_cache_hits,
        snap_on.coalesced_jobs
    );
    println!(
        "coalescing off: {} PreparedB builds for {} jobs",
        snap_off.prepare_builds, snap_off.jobs_completed
    );
    println!("serve speedup from coalescing: {speedup:.2}x");

    let out_path =
        std::env::var("SPMM_BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    let summary = obj([
        ("bench", Json::from("bench_serve/coalescing")),
        (
            "workload",
            Json::from(format!(
                "{JOBS} jobs sharing one B (256x512 @ 5%), A 48x256 @ 8%, \
                 {WORKERS} workers, inner-incrs kernel"
            )),
        ),
        ("jobs", Json::from(JOBS)),
        ("workers", Json::from(WORKERS)),
        ("coalesce_on_ms", Json::from(r_on.median.as_secs_f64() * 1e3)),
        ("coalesce_off_ms", Json::from(r_off.median.as_secs_f64() * 1e3)),
        ("speedup", Json::from(speedup)),
        ("builds_on", Json::from(snap_on.prepare_builds)),
        ("builds_off", Json::from(snap_off.prepare_builds)),
        ("cache_hits_on", Json::from(snap_on.prepare_cache_hits)),
        ("coalesced_jobs_on", Json::from(snap_on.coalesced_jobs)),
        ("coalesced_batches_on", Json::from(snap_on.coalesced_batches)),
    ]);
    match std::fs::write(&out_path, summary.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => println!("could not write {out_path}: {e}"),
    }

    // learned selection: train a cost model through the serving loop (the
    // refit cadence persists it), then serve the same batch twice — once
    // with the model warm-loaded, once on static cost hints — and compare
    // per-job latency percentiles
    let model_path = std::env::temp_dir()
        .join(format!("spmm_bench_cost_model_{}.txt", std::process::id()));
    let (snap_train, _) = serve_auto(
        LearnConfig {
            refit_every: 8,
            min_samples: 2,
            model_path: Some(model_path.clone()),
            ..Default::default()
        },
        &a_set,
        &b,
    );
    let (snap_fit, wall_fit_ms) = serve_auto(
        LearnConfig {
            refit_every: 0,
            model_path: Some(model_path.clone()),
            ..Default::default()
        },
        &a_set,
        &b,
    );
    let (snap_static, wall_static_ms) = serve_auto(LearnConfig::default(), &a_set, &b);
    std::fs::remove_file(&model_path).ok();
    println!(
        "selection (trained over {} refits): fitted p50={}us p99={}us, \
         static p50={}us p99={}us",
        snap_train.model_refits,
        snap_fit.p50_us,
        snap_fit.p99_us,
        snap_static.p50_us,
        snap_static.p99_us
    );

    let sel_path = std::env::var("SPMM_BENCH_SELECTION_OUT")
        .unwrap_or_else(|_| "BENCH_selection.json".into());
    let sel = obj([
        ("bench", Json::from("bench_serve/learned_selection")),
        (
            "workload",
            Json::from(format!(
                "{JOBS} auto-selected jobs sharing one B (256x512 @ 5%), A 48x256 @ 8%, \
                 {WORKERS} workers; model trained in-serve (refit every 8), then warm-loaded"
            )),
        ),
        ("jobs", Json::from(JOBS)),
        ("workers", Json::from(WORKERS)),
        ("train_model_refits", Json::from(snap_train.model_refits)),
        ("fitted_p50_us", Json::from(snap_fit.p50_us)),
        ("fitted_p99_us", Json::from(snap_fit.p99_us)),
        ("static_p50_us", Json::from(snap_static.p50_us)),
        ("static_p99_us", Json::from(snap_static.p99_us)),
        ("fitted_wall_ms", Json::from(wall_fit_ms)),
        ("static_wall_ms", Json::from(wall_static_ms)),
    ]);
    match std::fs::write(&sel_path, sel.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {sel_path}"),
        Err(e) => println!("could not write {sel_path}: {e}"),
    }

    // socket transport: the same sharded job over two loopback socket
    // workers (real OS sockets, full wire serialization) vs the in-process
    // channel transport vs unsharded — bit-identity asserted, so this is
    // both a perf number and a distributed-correctness smoke
    const SHARDS: usize = 4;
    let geom = Geometry::default();
    let spawn_worker = || {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind worker");
        let addr = listener.local_addr().expect("worker addr").to_string();
        let reg = Arc::new(Registry::with_default_kernels(geom, 2));
        std::thread::spawn(move || {
            let _ = remote::serve(listener, reg);
        });
        addr
    };
    let peers = vec![spawn_worker(), spawn_worker()];
    let socket = SocketTransport::connect(&peers).expect("connect to loopback workers");
    let leader = Registry::with_default_kernels(geom, 2);
    let kernel = leader
        .resolve(FormatKind::Csr, Algorithm::Tiled)
        .expect("tiled kernel");
    let ta = uniform(1024, 1024, 0.02, 7);
    let tb = uniform(1024, 512, 0.03, 8);
    let prepared = kernel.prepare(&tb).expect("prepare B");
    let cfg = ShardConfig { shards: SHARDS, block: geom.block };
    let local = shard::execute(kernel.as_ref(), &ta, Some(&tb), &prepared, cfg)
        .expect("in-process sharded run");
    let over_socket = shard::execute_with(&socket, kernel.as_ref(), &ta, Some(&tb), &prepared, cfg)
        .expect("socket sharded run");
    let unsharded = kernel.execute(&ta, &prepared).expect("unsharded run");
    assert_eq!(
        over_socket.c.bit_pattern(),
        local.c.bit_pattern(),
        "socket transport diverged from in-process"
    );
    assert_eq!(
        over_socket.c.bit_pattern(),
        unsharded.c.bit_pattern(),
        "socket transport diverged from unsharded"
    );

    let r_local = bench(1, 3, || {
        let out = shard::execute(kernel.as_ref(), &ta, Some(&tb), &prepared, cfg)
            .expect("in-process sharded run");
        black_box(out.stats.real_pairs);
    });
    report(&format!("transport/in_process_{SHARDS}_shards"), r_local, 1.0, "jobs");
    let r_socket = bench(1, 3, || {
        let out = shard::execute_with(&socket, kernel.as_ref(), &ta, Some(&tb), &prepared, cfg)
            .expect("socket sharded run");
        black_box(out.stats.real_pairs);
    });
    report(&format!("transport/socket_{SHARDS}_shards"), r_socket, 1.0, "jobs");
    let overhead = r_socket.median.as_secs_f64() / r_local.median.as_secs_f64();
    println!(
        "socket transport: {} remote band(s)/job, {} B replication(s) total, \
         {:.2}x in-process wall",
        over_socket.counters.remote_bands,
        over_socket.counters.prepare_replications,
        overhead
    );

    let tr_path = std::env::var("SPMM_BENCH_TRANSPORT_OUT")
        .unwrap_or_else(|_| "BENCH_transport.json".into());
    let tr = obj([
        ("bench", Json::from("bench_serve/shard_transport")),
        (
            "workload",
            Json::from(format!(
                "tiled kernel, A 1024x1024 @ 2%, B 1024x512 @ 3%, {SHARDS} row-band \
                 shards over 2 loopback socket workers vs in-process channels \
                 (bit-identity asserted)"
            )),
        ),
        ("shards", Json::from(SHARDS)),
        ("workers", Json::from(peers.len())),
        ("in_process_ms", Json::from(r_local.median.as_secs_f64() * 1e3)),
        ("socket_ms", Json::from(r_socket.median.as_secs_f64() * 1e3)),
        ("socket_overhead", Json::from(overhead)),
        ("remote_bands_per_job", Json::from(over_socket.counters.remote_bands)),
        ("prepare_replications", Json::from(over_socket.counters.prepare_replications)),
        ("bit_identical", Json::from(true)),
    ]);
    match std::fs::write(&tr_path, tr.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {tr_path}"),
        Err(e) => println!("could not write {tr_path}: {e}"),
    }

    // admission: the same burst against one worker, gated vs ungated.
    // Ungated, every job queues and the p99 queue wait absorbs the whole
    // backlog; gated, the excess is shed at the door with a typed
    // `Overloaded { retry_after }` and the tail of what IS admitted stays
    // bounded — the shed-vs-block tradeoff, quantified
    let burst = |budget: Option<std::time::Duration>| {
        let server = Server::start(ServerConfig {
            workers: 1,
            queue_depth: 64,
            kernel: KernelSpec::Fixed(FormatKind::InCrs, Algorithm::Inner),
            geometry: Geometry::default(),
            admission: AdmissionConfig { max_queue_delay: budget, ..Default::default() },
            ..Default::default()
        });
        let client = server.client();
        // train the service-rate estimate (an untrained gate admits all)
        client
            .job(Arc::clone(&a_set[0]), Arc::clone(&b))
            .id(9_000)
            .keep_result(false)
            .submit()
            .expect("training job admitted")
            .wait()
            .expect("training job");
        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        let mut shed = 0u64;
        for (i, a) in a_set.iter().enumerate() {
            let job = client
                .job(Arc::clone(a), Arc::clone(&b))
                .id(i as u64)
                .keep_result(false)
                .build();
            match client.submit(job) {
                Ok(h) => handles.push(h),
                Err(JobError::Overloaded { .. }) => shed += 1,
                Err(e) => panic!("unexpected rejection: {e}"),
            }
        }
        for res in JobHandle::batch_wait_all(handles) {
            black_box(res.expect("admitted job ok").report.real_pairs);
        }
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let snap = client.metrics();
        drop(client);
        server.shutdown();
        (snap, shed, wall_ms)
    };
    const GATE_US: u64 = 500;
    let (snap_open, shed_open, wall_open_ms) = burst(None);
    let (snap_gated, shed_gated, wall_gated_ms) =
        burst(Some(std::time::Duration::from_micros(GATE_US)));
    assert_eq!(shed_open, 0, "an ungated server must not shed");
    println!(
        "admission ({GATE_US}us budget): gated shed {shed_gated}/{JOBS}, \
         queue p99 {}us (ungated {}us), job p99 {}us (ungated {}us)",
        snap_gated.queue_p99_us, snap_open.queue_p99_us, snap_gated.p99_us, snap_open.p99_us
    );

    let adm_path = std::env::var("SPMM_BENCH_ADMISSION_OUT")
        .unwrap_or_else(|_| "BENCH_admission.json".into());
    let adm = obj([
        ("bench", Json::from("bench_serve/admission")),
        (
            "workload",
            Json::from(format!(
                "{JOBS}-job burst sharing one B (256x512 @ 5%), A 48x256 @ 8%, \
                 1 worker, inner-incrs kernel; ungated vs a {GATE_US}us \
                 queue-delay budget (service rate pre-trained)"
            )),
        ),
        ("jobs", Json::from(JOBS)),
        ("budget_us", Json::from(GATE_US)),
        ("gated_shed", Json::from(shed_gated)),
        ("gated_completed", Json::from(snap_gated.jobs_completed)),
        ("gated_queue_p99_us", Json::from(snap_gated.queue_p99_us)),
        ("gated_p99_us", Json::from(snap_gated.p99_us)),
        ("gated_wall_ms", Json::from(wall_gated_ms)),
        ("ungated_queue_p99_us", Json::from(snap_open.queue_p99_us)),
        ("ungated_p99_us", Json::from(snap_open.p99_us)),
        ("ungated_wall_ms", Json::from(wall_open_ms)),
    ]);
    match std::fs::write(&adm_path, adm.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {adm_path}"),
        Err(e) => println!("could not write {adm_path}: {e}"),
    }
}
