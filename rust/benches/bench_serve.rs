//! Serve-path throughput benchmark: N jobs sharing one `B` operand pumped
//! through `SpmmClient::submit_many`, with B-sharing micro-batch coalescing
//! on vs off. The prepare-heavy inner-InCRS kernel makes the amortization
//! visible: coalescing builds `PreparedB` once per worker (then the LRU
//! serves it), the uncoalesced path builds it once per job.
//!
//! Writes a machine-readable summary to `BENCH_serve.json` (override the
//! path with `SPMM_BENCH_SERVE_OUT`).
//!
//! Run: `cargo bench --bench bench_serve`

use std::sync::Arc;

use spmm_accel::coordinator::{
    CoalesceConfig, JobHandle, KernelSpec, MetricsSnapshot, Server, ServerConfig,
};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::Algorithm;
use spmm_accel::formats::csr::Csr;
use spmm_accel::formats::traits::FormatKind;
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::bench::{bench, black_box, report, BenchResult};
use spmm_accel::util::json::{obj, Json};

const JOBS: usize = 32;
const WORKERS: usize = 4;

fn serve_batch(coalesce: bool, a_set: &[Arc<Csr>], b: &Arc<Csr>) -> MetricsSnapshot {
    let server = Server::start(ServerConfig {
        workers: WORKERS,
        queue_depth: 32,
        // inner-product over InCRS: prepare builds the counter vectors,
        // the cost the paper (and the coalescer) amortizes
        kernel: KernelSpec::Fixed(FormatKind::InCrs, Algorithm::Inner),
        geometry: Geometry::default(),
        coalesce: CoalesceConfig { enabled: coalesce, ..Default::default() },
        ..Default::default()
    });
    let client = server.client();
    let jobs = a_set
        .iter()
        .enumerate()
        .map(|(i, a)| {
            client
                .job(Arc::clone(a), Arc::clone(b))
                .id(i as u64)
                .keep_result(false)
                .build()
        })
        .collect::<Vec<_>>();
    let handles = client.submit_many(jobs);
    for res in JobHandle::batch_wait_all(handles) {
        black_box(res.expect("job ok").report.real_pairs);
    }
    let snap = client.metrics();
    drop(client);
    server.shutdown();
    snap
}

fn run_case(coalesce: bool, a_set: &[Arc<Csr>], b: &Arc<Csr>) -> (BenchResult, MetricsSnapshot) {
    let r = bench(1, 3, || {
        black_box(serve_batch(coalesce, a_set, b).jobs_completed);
    });
    let snap = serve_batch(coalesce, a_set, b);
    (r, snap)
}

fn main() {
    println!("== bench_serve ==");
    // one shared B (docword-ish: wide, moderately dense rows — the InCRS
    // counter build is a real cost), many distinct As; sized so the whole
    // on/off comparison stays a CI-friendly smoke
    let b = Arc::new(uniform(256, 512, 0.05, 99));
    let a_set: Vec<Arc<Csr>> = (0..JOBS as u64)
        .map(|i| Arc::new(uniform(48, 256, 0.08, i)))
        .collect();

    let (r_on, snap_on) = run_case(true, &a_set, &b);
    report(
        &format!("serve/{JOBS}_jobs_shared_b_coalesce_on"),
        r_on,
        JOBS as f64,
        "jobs",
    );
    let (r_off, snap_off) = run_case(false, &a_set, &b);
    report(
        &format!("serve/{JOBS}_jobs_shared_b_coalesce_off"),
        r_off,
        JOBS as f64,
        "jobs",
    );

    let speedup = r_off.median.as_secs_f64() / r_on.median.as_secs_f64();
    println!(
        "coalescing on:  {} PreparedB builds for {} jobs ({} cache hits, {} coalesced)",
        snap_on.prepare_builds, snap_on.jobs_completed, snap_on.prepare_cache_hits,
        snap_on.coalesced_jobs
    );
    println!(
        "coalescing off: {} PreparedB builds for {} jobs",
        snap_off.prepare_builds, snap_off.jobs_completed
    );
    println!("serve speedup from coalescing: {speedup:.2}x");

    let out_path =
        std::env::var("SPMM_BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    let summary = obj([
        ("bench", Json::from("bench_serve/coalescing")),
        (
            "workload",
            Json::from(format!(
                "{JOBS} jobs sharing one B (256x512 @ 5%), A 48x256 @ 8%, \
                 {WORKERS} workers, inner-incrs kernel"
            )),
        ),
        ("jobs", Json::from(JOBS)),
        ("workers", Json::from(WORKERS)),
        ("coalesce_on_ms", Json::from(r_on.median.as_secs_f64() * 1e3)),
        ("coalesce_off_ms", Json::from(r_off.median.as_secs_f64() * 1e3)),
        ("speedup", Json::from(speedup)),
        ("builds_on", Json::from(snap_on.prepare_builds)),
        ("builds_off", Json::from(snap_off.prepare_builds)),
        ("cache_hits_on", Json::from(snap_on.prepare_cache_hits)),
        ("coalesced_jobs_on", Json::from(snap_on.coalesced_jobs)),
        ("coalesced_batches_on", Json::from(snap_on.coalesced_batches)),
    ]);
    match std::fs::write(&out_path, summary.to_string_pretty() + "\n") {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => println!("could not write {out_path}: {e}"),
    }
}
