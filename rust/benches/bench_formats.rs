//! Format-layer microbenchmarks: locate throughput per format, InCRS build
//! rate, column reads, conversions. (custom harness; criterion unavailable)

use spmm_accel::access::column::{read_columns_csr, read_columns_incrs};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::formats::convert::{from_coo, ALL_KINDS};
use spmm_accel::formats::incrs::InCrs;
use spmm_accel::formats::traits::{CountSink, NullSink, SparseMatrix};
use spmm_accel::util::bench::{bench, black_box, report};
use spmm_accel::util::rng::Rng;

fn main() {
    println!("== bench_formats ==");
    let m = uniform(400, 8192, 0.05, 7);
    let coo = m.to_coo();
    let probes = 20_000usize;

    // locate throughput per format (NullSink: pure locate cost)
    for kind in ALL_KINDS {
        let mat = from_coo(kind, &coo).unwrap();
        let mut rng = Rng::new(3);
        let coords: Vec<(usize, usize)> = (0..probes)
            .map(|_| (rng.usize_below(400), rng.usize_below(8192)))
            .collect();
        let r = bench(1, 5, || {
            let mut sink = NullSink;
            let mut hits = 0u32;
            for &(i, j) in &coords {
                if mat.locate_dyn(i, j, &mut sink).is_some() {
                    hits += 1;
                }
            }
            black_box(hits);
        });
        report(
            &format!("locate/{}", kind.name()),
            r,
            probes as f64,
            "probes",
        );
    }

    // InCRS construction rate
    let r = bench(1, 10, || {
        black_box(InCrs::from_csr(&m).unwrap());
    });
    report("incrs/build", r, m.nnz() as f64, "nnz");

    // full column-order read, counting sink (Table II inner loop)
    let incrs = InCrs::from_csr(&m).unwrap();
    let r = bench(1, 3, || {
        let mut sink = CountSink::default();
        black_box(read_columns_csr(&m, Some(512), &mut sink));
        black_box(sink.total);
    });
    report("column_read/crs(512 cols)", r, 512.0 * 400.0, "cells");
    let r = bench(1, 3, || {
        let mut sink = CountSink::default();
        black_box(read_columns_incrs(&incrs, Some(512), &mut sink));
        black_box(sink.total);
    });
    report("column_read/incrs(512 cols)", r, 512.0 * 400.0, "cells");

    // conversion throughput via COO
    for kind in ALL_KINDS {
        let r = bench(1, 3, || {
            black_box(from_coo(kind, &coo).unwrap().nnz());
        });
        report(&format!("convert/{}", kind.name()), r, m.nnz() as f64, "nnz");
    }
}
