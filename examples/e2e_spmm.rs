//! END-TO-END driver: the full system on a real (synthetic, spec-matched)
//! workload — proves all three layers compose (EXPERIMENTS.md §E2E).
//!
//! Pipeline, per the paper's story:
//!   1. dataset: Docword-scale sparse matrix (Table II spec), stored
//!      row-ordered;
//!   2. routing: the coordinator decides InCRS pays off (N·D/(b+2) >> 1);
//!   3. representation: InCRS build + measured column-access MA ratio and
//!      cache-simulated time ratio vs CRS (contribution 1);
//!   4. architecture: cycle-accurate latency of the synchronized mesh vs
//!      FPIC and conventional MM at the Table V design points
//!      (contribution 2);
//!   5. numerics: the same multiplication executed for real, batched
//!      through the coordinator onto the AOT-compiled Pallas block-sparse
//!      kernel via PJRT (CPU fallback when artifacts are absent), verified
//!      against the CPU oracle;
//!   6. serving: a batch of jobs through the worker pool with metrics.
//!
//! Run: `make artifacts && cargo run --release --example e2e_spmm`
//! (add `--scale 0.25` style args via env E2E_SCALE for quicker runs)

use std::sync::Arc;
use std::time::Instant;

use spmm_accel::arch::{
    conv_cycles, fpic_simulate, model, sync_cycle_model, ConvMmConfig, FpicConfig,
    SyncMeshConfig,
};
use spmm_accel::cachesim::{compare, HierarchyConfig};
use spmm_accel::coordinator::{
    route, JobHandle, RoutingPolicy, Server, ServerConfig,
};
use spmm_accel::datasets::spec::table2_by_name;
use spmm_accel::datasets::synth::generate;
use spmm_accel::formats::incrs::InCrsParams;
use spmm_accel::formats::traits::SparseMatrix;
use spmm_accel::runtime::Manifest;
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::tables::{human, sig};

fn main() {
    let scale: f64 = std::env::var("E2E_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let t0 = Instant::now();
    println!("=== spmm-accel end-to-end driver (scale {scale}) ===\n");

    // ---- 1. workload -----------------------------------------------------
    let mut spec = table2_by_name("docword").expect("registry");
    spec.rows = ((spec.rows as f64 * scale) as usize).max(64);
    let b = generate(&spec, 42);
    let a = generate(
        &spmm_accel::datasets::spec::DatasetSpec {
            name: "driver",
            rows: 128,
            cols: spec.rows,
            stated_density: 0.1,
            nnz_row: spmm_accel::datasets::spec::NnzRow {
                min: 1,
                avg: 0.1 * spec.rows as f64,
                max: (0.25 * spec.rows as f64) as usize,
            },
            dist: spmm_accel::datasets::spec::ColumnDist::Uniform,
        },
        43,
    );
    println!(
        "[1] workload: A {}x{} (nnz {}), B=docword {}x{} (nnz {}, D {:.1}%)",
        a.rows(), a.cols(), human(a.nnz() as u64),
        b.rows(), b.cols(), human(b.nnz() as u64), b.density() * 100.0
    );

    // ---- 2. routing -------------------------------------------------------
    let artifacts = Manifest::default_dir().join("manifest.json").exists();
    let r = route(&b, true, artifacts, &RoutingPolicy::default());
    println!(
        "[2] route: access={:?} kernel={}/{} (est. MA ratio {})",
        r.access,
        r.kernel.0.name(),
        r.kernel.1.name(),
        sig(r.estimated_ma_ratio)
    );

    // ---- 3. representation (contribution 1) -------------------------------
    let cols_probe = ((b.cols() as f64 * scale) as usize).max(128);
    let cmp = compare(
        &b,
        InCrsParams::default(),
        HierarchyConfig::default(),
        Some(cols_probe),
    )
    .expect("cache comparison");
    println!(
        "[3] InCRS vs CRS column read ({} cols probed): L1 accesses {}x, \
         mem time {}x, total time {}x  (paper: 14-49x)",
        cols_probe,
        sig(cmp.l1_access_ratio()),
        sig(cmp.mem_time_ratio()),
        sig(cmp.total_time_ratio()),
    );

    // ---- 4. architecture (contribution 2) ---------------------------------
    let sync = sync_cycle_model(&b, &b, SyncMeshConfig::default());
    let (fpic_bw, _) = fpic_simulate(
        &b,
        &b,
        FpicConfig { units: model::fpic_units_same_bandwidth(64), ..FpicConfig::default() },
    );
    let (fpic_buf, _) = fpic_simulate(
        &b,
        &b,
        FpicConfig { units: model::fpic_units_same_buffer(64), ..FpicConfig::default() },
    );
    let conv = conv_cycles(b.rows(), b.rows(), b.cols(), ConvMmConfig::default());
    println!(
        "[4] B x Bᵀ latency (cycles): sync mesh {} | FPIC-sameBW {} ({}x) | \
         FPIC-sameBuf {} ({}x) | conv MM {} ({}x)   (paper: FPIC 2-30x, conv 1.5-39x)",
        human(sync.cycles),
        human(fpic_bw.cycles),
        sig(fpic_bw.cycles as f64 / sync.cycles as f64),
        human(fpic_buf.cycles),
        sig(fpic_buf.cycles as f64 / sync.cycles as f64),
        human(conv.cycles),
        sig(conv.cycles as f64 / sync.cycles as f64),
    );
    println!(
        "    sync mesh: {} passes, {} useful MACs, utilization {:.2}%",
        human(sync.passes),
        human(sync.macs),
        sync.utilization(64) * 100.0
    );

    // ---- 5 & 6. numerics through the serving stack ------------------------
    let server = Server::start(ServerConfig {
        workers: 2,
        queue_depth: 8,
        prefer_pjrt: artifacts,
        geometry: Geometry::default(),
        artifacts_dir: Manifest::default_dir(),
        ..Default::default()
    });
    let client = server.client();
    let a = Arc::new(a);
    let b = Arc::new(b);
    let n_jobs = 8u64;
    let t_serve = Instant::now();
    // all jobs share B -> one PreparedB build amortizes across the batch
    let batch = (0..n_jobs).map(|i| {
        client
            .job(a.clone(), b.clone())
            .id(i)
            .verify(i == 0) // verify the first job against the oracle
            .keep_result(false)
            .build()
    });
    let handles = client.submit_many(batch);
    let mut max_err = 0.0f32;
    let mut dispatches = 0u64;
    let mut pairs = 0u64;
    let mut backend = "";
    for res in JobHandle::batch_wait_all(handles) {
        let out = res.expect("job ok");
        if let Some(e) = out.max_err {
            max_err = max_err.max(e);
        }
        dispatches += out.report.dispatches;
        pairs += out.report.real_pairs;
        backend = out.backend;
    }
    let serve_wall = t_serve.elapsed();
    let snap = server.metrics.snapshot();
    println!(
        "[5] numerics: {n_jobs} jobs on backend={backend}: {} dispatches, {} tile pairs, \
         verified max|err| {:.2e}",
        human(dispatches),
        human(pairs),
        max_err
    );
    println!(
        "[6] serving: {:?} wall, p50 {} us, p99 {} us, throughput {:.1} jobs/s, \
         {} PreparedB builds for {n_jobs} jobs ({} coalesced)",
        serve_wall,
        snap.p50_us,
        snap.p99_us,
        n_jobs as f64 / serve_wall.as_secs_f64(),
        snap.prepare_builds,
        snap.coalesced_jobs
    );
    drop(client);
    server.shutdown();

    assert!(max_err < 1e-2, "numeric verification failed: {max_err}");
    assert!(cmp.total_time_ratio() > 1.0, "InCRS must beat CRS end to end");
    assert!(fpic_bw.cycles > sync.cycles, "sync mesh must beat FPIC");
    println!("\nE2E OK in {:?} — all layers compose.", t0.elapsed());
}
