//! Serving demo: the batching coordinator under concurrent load, with
//! backpressure and live metrics — the L3 "accelerator service" shape.
//!
//! Run: `cargo run --release --example serve_demo -- \
//!         --workers 4 --clients 3 --jobs-per-client 10 [--backend pjrt]`

use std::sync::Arc;
use std::time::Instant;

use spmm_accel::coordinator::{
    JobOptions, KernelSpec, Server, ServerConfig, SpmmJob,
};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::Algorithm;
use spmm_accel::runtime::Manifest;
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::args::Args;

fn main() {
    let args = Args::from_env();
    let workers = args.get_or("workers", 4usize).unwrap();
    let clients = args.get_or("clients", 3usize).unwrap();
    let jobs_per_client = args.get_or("jobs-per-client", 10usize).unwrap();
    let backend = args.str_or("backend", "cpu").to_string();

    // jobs resolve through the kernel registry: the block (accelerator
    // plan) kernel by default, PJRT-backed when artifacts are available;
    // KernelSpec::for_algorithm maps each algorithm to the B-format its
    // kernel is registered under (shared with the `spmm-accel` CLI)
    let kernel = match args.str_or("kernel", "block") {
        "auto" => KernelSpec::Auto,
        name => KernelSpec::for_algorithm(Algorithm::parse(name).expect("--kernel")),
    };
    let server = Arc::new(Server::start(ServerConfig {
        workers,
        queue_depth: 4, // small on purpose: exercise backpressure
        kernel,
        prefer_pjrt: backend == "pjrt",
        geometry: Geometry::default(),
        tile_workers: args.get_or("tile-workers", 1usize).unwrap(),
        artifacts_dir: Manifest::default_dir(),
    }));

    println!(
        "server: {workers} workers ({backend}), {clients} clients x {jobs_per_client} jobs, queue depth 4"
    );
    let t0 = Instant::now();

    // client threads submit mixed-size jobs; small queue forces blocking
    // submits (backpressure) under burst
    let mut handles = Vec::new();
    for cid in 0..clients {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            let mut rejected = 0u64;
            let mut done = 0u64;
            for j in 0..jobs_per_client {
                let n = 64 + (j % 3) * 64;
                let a = Arc::new(uniform(n, n, 0.08, (cid * 1000 + j) as u64));
                let job = SpmmJob::new(
                    (cid * jobs_per_client + j) as u64,
                    a.clone(),
                    a,
                )
                .with_opts(JobOptions {
                    verify: false,
                    keep_result: false,
                    kernel: None,
                });
                // first try without blocking, then block (backpressure)
                let rx = match server.try_submit(job) {
                    Ok(rx) => rx,
                    Err(job) => {
                        rejected += 1;
                        server.submit(job)
                    }
                };
                let res = rx.recv().expect("response");
                assert!(res.result.is_ok(), "{:?}", res.result.err());
                done += 1;
            }
            (done, rejected)
        }));
    }

    let mut total_done = 0;
    let mut total_rejected = 0;
    for h in handles {
        let (d, r) = h.join().unwrap();
        total_done += d;
        total_rejected += r;
    }
    let wall = t0.elapsed();
    let snap = server.metrics.snapshot();
    println!(
        "done: {total_done} jobs in {wall:?} ({:.1} jobs/s), {total_rejected} fast-path rejections (backpressure)",
        total_done as f64 / wall.as_secs_f64()
    );
    println!(
        "metrics: completed={} failed={} dispatches={} tile-pairs={} p50={}us p99={}us \
         queue p50={}us p99={}us busy={:.1}ms",
        snap.jobs_completed,
        snap.jobs_failed,
        snap.dispatches,
        snap.real_pairs,
        snap.p50_us,
        snap.p99_us,
        snap.queue_p50_us,
        snap.queue_p99_us,
        snap.busy_ns as f64 / 1e6
    );
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown(),
        Err(_) => unreachable!("all clients joined"),
    }
}
