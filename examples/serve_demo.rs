//! Serving demo: the batching coordinator under concurrent load through
//! the `SpmmClient` API — backpressure, typed errors, B-sharing micro-batch
//! coalescing, and live metrics: the L3 "accelerator service" shape.
//!
//! Each client thread holds its own `SpmmClient` clone and replays a
//! serving-shaped workload: many multiplies against a small set of shared
//! `B` operands (the paper's amortization case). Fast-path `try_submit`
//! falls back to the blocking `submit` on `JobError::QueueFull`.
//!
//! Run: `cargo run --release --example serve_demo -- \
//!         --workers 4 --clients 3 --jobs-per-client 10 \
//!         [--backend pjrt] [--no-coalesce]`

use std::sync::Arc;
use std::time::Instant;

use spmm_accel::coordinator::{
    CoalesceConfig, JobError, KernelSpec, Server, ServerConfig,
};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::Algorithm;
use spmm_accel::runtime::Manifest;
use spmm_accel::spmm::plan::Geometry;
use spmm_accel::util::args::Args;

fn main() {
    let args = Args::from_env();
    let workers = args.get_or("workers", 4usize).unwrap();
    let clients = args.get_or("clients", 3usize).unwrap();
    let jobs_per_client = args.get_or("jobs-per-client", 10usize).unwrap();
    let backend = args.str_or("backend", "cpu").to_string();

    // jobs resolve through the kernel registry: the block (accelerator
    // plan) kernel by default, PJRT-backed when artifacts are available;
    // KernelSpec::for_algorithm maps each algorithm to the B-format its
    // kernel is registered under (shared with the `spmm-accel` CLI)
    let kernel = match args.str_or("kernel", "block") {
        "auto" => KernelSpec::Auto,
        name => KernelSpec::for_algorithm(Algorithm::parse(name).expect("--kernel")),
    };
    let coalesce = CoalesceConfig {
        enabled: !args.has("no-coalesce"),
        ..Default::default()
    };
    let server = Server::start(ServerConfig {
        workers,
        queue_depth: 4, // small on purpose: exercise backpressure
        kernel,
        prefer_pjrt: backend == "pjrt",
        geometry: Geometry::default(),
        tile_workers: args.get_or("tile-workers", 1usize).unwrap(),
        artifacts_dir: Manifest::default_dir(),
        coalesce,
        ..Default::default()
    });

    // a small pool of shared B operands: serving traffic reuses operands,
    // which is exactly what the coalescer amortizes prepare across
    let shared_b: Vec<Arc<_>> = (0..3u64)
        .map(|s| Arc::new(uniform(128, 96, 0.08, 500 + s)))
        .collect();

    println!(
        "server: {workers} workers ({backend}), {clients} clients x {jobs_per_client} jobs, \
         queue depth 4, coalescing {}",
        if coalesce.enabled { "on" } else { "off" }
    );
    let t0 = Instant::now();

    // client threads submit mixed-size jobs; the small queue forces the
    // try_submit fast path to degrade into blocking submits (backpressure)
    let mut handles = Vec::new();
    for cid in 0..clients {
        let client = server.client();
        let shared_b = shared_b.clone();
        handles.push(std::thread::spawn(move || {
            let mut backpressured = 0u64;
            let mut done = 0u64;
            for j in 0..jobs_per_client {
                let n = 64 + (j % 3) * 32;
                let a = Arc::new(uniform(n, 128, 0.08, (cid * 1000 + j) as u64));
                let b = Arc::clone(&shared_b[j % shared_b.len()]);
                let job = client.job(a, b).keep_result(false).build();
                // first try without blocking, then block (backpressure)
                let handle = match client.try_submit(job.clone()) {
                    Ok(h) => h,
                    Err(JobError::QueueFull) => {
                        backpressured += 1;
                        client.submit(job).expect("server alive")
                    }
                    Err(e) => panic!("submit failed: {e}"),
                };
                let out = handle.wait().expect("job ok");
                assert!(out.c.is_none(), "keep_result(false) drops the matrix");
                done += 1;
            }
            (done, backpressured)
        }));
    }

    let mut total_done = 0;
    let mut total_backpressured = 0;
    for h in handles {
        let (d, r) = h.join().unwrap();
        total_done += d;
        total_backpressured += r;
    }
    let wall = t0.elapsed();
    let snap = server.metrics.snapshot();
    println!(
        "done: {total_done} jobs in {wall:?} ({:.1} jobs/s), {total_backpressured} fast-path \
         rejections (backpressure)",
        total_done as f64 / wall.as_secs_f64()
    );
    println!(
        "metrics: completed={} failed={} dispatches={} tile-pairs={} p50={}us p99={}us \
         queue p50={}us p99={}us busy={:.1}ms",
        snap.jobs_completed,
        snap.jobs_failed,
        snap.dispatches,
        snap.real_pairs,
        snap.p50_us,
        snap.p99_us,
        snap.queue_p50_us,
        snap.queue_p99_us,
        snap.busy_ns as f64 / 1e6
    );
    println!(
        "coalescing: {} PreparedB builds for {} jobs ({} cache hits, {} coalesced jobs \
         in {} sharing groups)",
        snap.prepare_builds,
        snap.jobs_completed,
        snap.prepare_cache_hits,
        snap.coalesced_jobs,
        snap.coalesced_batches
    );
    server.shutdown();
}
