//! Quickstart: the public API in one minute.
//!
//! Build a sparse matrix, convert it to the paper's InCRS format, compare
//! random-access cost against CRS, multiply through the registry's
//! cost-hint auto-selection, then serve the same multiply through the
//! `SpmmClient` API (CPU fallback so it runs without artifacts).
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use spmm_accel::access::locate::measure;
use spmm_accel::coordinator::{Server, ServerConfig};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::engine::{Registry, SpmmKernel};
use spmm_accel::formats::incrs::InCrs;
use spmm_accel::formats::traits::{CountSink, SparseMatrix};
use spmm_accel::spmm::plan::Geometry;

fn main() {
    // 1. a synthetic "docword-like" sparse matrix: 200 x 4096 at 4% density
    let b = uniform(200, 4096, 0.04, 42);
    println!(
        "B: {}x{}, nnz={} (D={:.1}%)",
        b.rows(),
        b.cols(),
        b.nnz(),
        b.density() * 100.0
    );

    // 2. the paper's format: CRS + counter-vectors (S=256, b=32)
    let incrs = InCrs::from_csr(&b).expect("rows fit the 16-bit prefix");
    println!(
        "InCRS storage: {} words vs CRS {} words (ratio {:.3})",
        incrs.storage_words(),
        (b.rows() + 1) + 2 * b.nnz(),
        ((b.rows() + 1) + 2 * b.nnz()) as f64 / incrs.storage_words() as f64
    );

    // 3. random-access cost, CRS vs InCRS (Table I/II mechanism)
    let crs_cost = measure(&b, 20_000, 7).avg();
    let incrs_cost = measure(&incrs, 20_000, 7).avg();
    println!(
        "avg memory accesses to locate one element: CRS {crs_cost:.1}, \
         InCRS {incrs_cost:.1} -> {:.1}x fewer",
        crs_cost / incrs_cost
    );

    // 4. one full column read with explicit accounting
    let mut sink = CountSink::default();
    for i in 0..b.rows() {
        incrs.locate(i, 1234, &mut sink);
    }
    println!(
        "reading column 1234 through InCRS: {} accesses ({} counter words)",
        sink.total,
        sink.site(spmm_accel::formats::Site::Counter)
    );

    // 5. SpMM through the kernel registry's cost-hint auto-selection:
    //    `Registry::select` estimates every registered kernel (Gustavson /
    //    inner-InCRS / tiled / accelerator block plan) and runs the
    //    cheapest — no hardcoded kernel key.
    let registry = Registry::with_default_kernels(Geometry::default(), 4);
    let a = uniform(96, 200, 0.1, 1);
    let auto = registry.select(&a, &b).expect("non-empty registry");
    let out = auto.run(&a, &b).expect("spmm");
    let oracle = spmm_accel::spmm::dense::multiply(&a, &b);
    println!(
        "C = A x B via auto-selected {} ({}/{}): {}x{}, {} dispatches, max err {:.2e}",
        auto.name(),
        auto.format().name(),
        auto.algorithm().name(),
        out.c.shape().0,
        out.c.shape().1,
        out.stats.dispatches,
        out.c.max_abs_diff(&oracle)
    );

    // 6. the same multiply as serving traffic: a batching server, the
    //    SpmmClient front door, typed errors, and a JobHandle future
    let server = Server::start(ServerConfig::default());
    let client = server.client();
    let out = client
        .job(Arc::new(a), Arc::new(b))
        .verify(true)
        .submit()
        .expect("accepted")
        .wait()
        .expect("job ok");
    println!(
        "served via {}: wall {:?}, max err {:.2e} ({} PreparedB builds)",
        out.backend,
        out.wall,
        out.max_err.unwrap(),
        client.metrics().prepare_builds
    );
    drop(client);
    server.shutdown();
}
