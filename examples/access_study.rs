//! Access study: explore the InCRS design space on your own parameters —
//! the Table I/II machinery as an interactive tool.
//!
//! Run: `cargo run --release --example access_study -- \
//!         --rows 500 --cols 8192 --density 0.05 --sections 256 --blocks 8,16,32,64`

use spmm_accel::access::column::{read_columns_csr, read_columns_incrs};
use spmm_accel::access::locate::{measure, analytic_cost};
use spmm_accel::datasets::synth::uniform;
use spmm_accel::formats::convert::{from_coo, ALL_KINDS};
use spmm_accel::formats::incrs::{InCrs, InCrsParams};
use spmm_accel::formats::traits::{CountSink, SparseMatrix};
use spmm_accel::util::args::Args;
use spmm_accel::util::tables::{sig, Table};

fn main() {
    let args = Args::from_env();
    let rows = args.get_or("rows", 300usize).unwrap();
    let cols = args.get_or("cols", 8192usize).unwrap();
    let density = args.get_or("density", 0.05f64).unwrap();
    let section = args.get_or("sections", 256usize).unwrap();
    let blocks: Vec<usize> = args.list("blocks").unwrap().unwrap_or(vec![8, 16, 32, 64]);
    let seed = args.get_or("seed", 1u64).unwrap();

    let m = uniform(rows, cols, density, seed);
    let coo = m.to_coo();
    println!(
        "matrix: {rows}x{cols} D={:.2}% nnz={}\n",
        m.density() * 100.0,
        m.nnz()
    );

    // Part 1: every format's random-access cost (Table I)
    let mut t1 = Table::new(
        "random-access cost by format",
        &["format", "analytic", "measured avg MA", "storage words"],
    );
    for kind in ALL_KINDS {
        let mat = from_coo(kind, &coo).unwrap();
        let cost = measure(mat.as_ref(), 10_000, seed + 1);
        t1.row(vec![
            kind.name().to_string(),
            analytic_cost(mat.as_ref()).map(sig).unwrap_or_default(),
            sig(cost.avg()),
            mat.storage_words().to_string(),
        ]);
    }
    t1.print();

    // Part 2: InCRS block-size sweep (the paper's S/b tradeoff, §III.C:
    // "by reducing the size of the blocks the storage overhead and the
    // expected benefit both increase")
    let mut t2 = Table::new(
        &format!("InCRS design sweep (S={section})"),
        &[
            "b", "counter bits", "est MA ratio", "meas MA ratio (col read)",
            "storage ratio", "build ok",
        ],
    );
    for &b in &blocks {
        let params = InCrsParams { section, block: b };
        if let Err(e) = params.validate() {
            t2.row(vec![
                b.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("no: {e}"),
            ]);
            continue;
        }
        let incrs = match InCrs::from_csr_params(&m, params) {
            Ok(x) => x,
            Err(e) => {
                t2.row(vec![
                    b.to_string(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    e.to_string(),
                ]);
                continue;
            }
        };
        let mut c_crs = CountSink::default();
        read_columns_csr(&m, Some(cols / 8), &mut c_crs);
        let mut c_in = CountSink::default();
        read_columns_incrs(&incrs, Some(cols / 8), &mut c_in);
        let crs_words = (rows + 1) + 2 * m.nnz();
        t2.row(vec![
            b.to_string(),
            format!(
                "16+{}x{}",
                params.blocks_per_section(),
                params.bits_per_block()
            ),
            sig(incrs.estimated_ma_ratio()),
            sig(c_crs.total as f64 / c_in.total as f64),
            sig(crs_words as f64 / incrs.storage_words() as f64),
            "yes".into(),
        ]);
    }
    t2.print();
}
