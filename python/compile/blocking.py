"""Reference planner: dense/CSR matrices -> sorted tile-pair dispatches.

This is the *Python mirror* of ``rust/src/spmm/{blocks,plan}.rs``: numpy-only,
used by the pytest suite to validate the kernel contract end-to-end (dense
matrices -> blocking -> pair matching -> kernel dispatches -> scatter ->
dense product).  Keeping the two planners behaviourally identical is part of
the test surface (rust integration tests replay fixture plans emitted here —
see tests/test_pipeline.py which stores golden plans).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Dispatch:
    """One accelerator call: P pairs (padded), <=T distinct output slots."""

    seg: np.ndarray          # int32[P] sorted, padding repeats last real id
    a: np.ndarray            # f32[P, B, B]
    b: np.ndarray            # f32[P, B, B]
    n_real: int              # pairs before padding
    # slot -> (output block row, output block col); only visited slots listed
    slot_map: list


def _nonzero_blocks(m, block):
    """Map {(bi, bk) -> dense tile} of the non-empty block grid of ``m``."""
    rows, cols = m.shape
    nbr = (rows + block - 1) // block
    nbc = (cols + block - 1) // block
    out = {}
    for bi in range(nbr):
        for bk in range(nbc):
            tile = m[bi * block:(bi + 1) * block, bk * block:(bk + 1) * block]
            if np.any(tile != 0):
                padded = np.zeros((block, block), m.dtype)
                padded[: tile.shape[0], : tile.shape[1]] = tile
                out[(bi, bk)] = padded
    return out


def plan(a_dense, b_dense, *, block, pairs, slots):
    """Match nonzero blocks of A and B along K, sort by output tile, chunk.

    The pair list is the block-granular version of the paper's comparator
    mesh output: only (nonzero x nonzero) work survives.
    """
    assert a_dense.shape[1] == b_dense.shape[0]
    ab = _nonzero_blocks(a_dense, block)
    bb = _nonzero_blocks(b_dense, block)

    # Index B's blocks by K-block for the intersection.
    b_by_k = {}
    for (bk, bj), tile in bb.items():
        b_by_k.setdefault(bk, []).append((bj, tile))

    # (out_bi, out_bj) -> [(a_tile, b_tile)], insertion-ordered by K.
    by_out = {}
    for (bi, bk) in sorted(ab.keys()):
        a_tile = ab[(bi, bk)]
        for bj, b_tile in b_by_k.get(bk, ()):
            by_out.setdefault((bi, bj), []).append((a_tile, b_tile))

    flat = []  # (out_coord, a_tile, b_tile), grouped by out_coord
    for out_coord in sorted(by_out):
        for a_tile, b_tile in by_out[out_coord]:
            flat.append((out_coord, a_tile, b_tile))

    dispatches = []
    i = 0
    while i < len(flat):
        seg, av, bv, slot_map, slot_of = [], [], [], [], {}
        while i < len(flat) and len(seg) < pairs:
            out_coord, a_tile, b_tile = flat[i]
            if out_coord not in slot_of:
                if len(slot_map) == slots:
                    break  # dispatch full on slots
                # never split one output tile's pair group across dispatches
                # unless it alone exceeds P (then revisit-accumulate resumes
                # in the next dispatch and the scatter side adds partials)
                slot_of[out_coord] = len(slot_map)
                slot_map.append(out_coord)
            seg.append(slot_of[out_coord])
            av.append(a_tile)
            bv.append(b_tile)
            i += 1
        n_real = len(seg)
        while len(seg) < pairs:  # pad: repeat last slot with zero tiles
            seg.append(seg[-1] if seg else 0)
            av.append(np.zeros_like(flat[0][1]) if flat else np.zeros((1, 1)))
            bv.append(np.zeros_like(flat[0][2]) if flat else np.zeros((1, 1)))
        dispatches.append(
            Dispatch(
                seg=np.asarray(seg, np.int32),
                a=np.stack(av),
                b=np.stack(bv),
                n_real=n_real,
                slot_map=slot_map,
            )
        )
    return dispatches


def scatter(dispatches, out_tiles_fn, m, n, *, block, dtype=np.float32):
    """Run ``out_tiles_fn(dispatch) -> (T,B,B)`` and assemble dense C."""
    nbr = (m + block - 1) // block
    nbc = (n + block - 1) // block
    c = np.zeros((nbr * block, nbc * block), dtype)
    for d in dispatches:
        tiles = np.asarray(out_tiles_fn(d))
        for slot, (bi, bj) in enumerate(d.slot_map):
            c[bi * block:(bi + 1) * block, bj * block:(bj + 1) * block] += \
                tiles[slot]
    return c[:m, :n]
