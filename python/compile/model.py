"""L2: the SpMM compute graphs that get AOT-lowered for the Rust runtime.

The paper's system splits cleanly: *where* the useful work is (index
matching) is decided by the coordinator; *doing* the work (MACs) is the
accelerator mesh.  At L2 this is a single fused graph per dispatch shape —
there is no Python on the request path, these functions exist only to be
``jax.jit(...).lower()``-ed once by ``aot.py``.

Graphs:
  * ``spmm_block_graph``  — primary: scalar-prefetch Pallas contraction.
  * ``spmm_pairs_graph``  — products-only fallback / ablation artifact.
  * ``dense_mm_graph``    — conventional-MM numeric twin.

All are shape-monomorphic per artifact; the dispatch geometry lives in the
manifest so the Rust planner and this file cannot drift apart silently.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import spmm_block as kernels

# Canonical artifact geometry — single source of truth, exported into
# artifacts/manifest.json and asserted by rust/src/runtime/artifact.rs.
BLOCK = kernels.BLOCK  # 32: tile edge == the paper's round size R
PAIRS = kernels.PAIRS  # 128: tile pairs per dispatch
SLOTS = kernels.SLOTS  # 64: output tile slots per dispatch
DENSE_DIM = 256        # dense_mm artifact operand edge


def spmm_block_graph(seg, a, b):
    """One accelerator dispatch: P sorted tile pairs -> T output tiles."""
    return (kernels.spmm_block(seg, a, b, slots=SLOTS, interpret=True),)


def spmm_pairs_graph(a, b):
    """Ablation/fallback dispatch: products only, accumulation downstream."""
    return (kernels.spmm_pairs(a, b, interpret=True),)


def dense_mm_graph(x, y):
    """Dense baseline dispatch (processes zeros, like the conventional MM)."""
    return (kernels.dense_mm(x, y, tile=64, interpret=True),)


def example_args(name, dtype=jnp.float32):
    """ShapeDtypeStructs used both for lowering and in the manifest."""
    f = jax.ShapeDtypeStruct
    if name == "spmm_block":
        return (
            f((PAIRS,), jnp.int32),
            f((PAIRS, BLOCK, BLOCK), dtype),
            f((PAIRS, BLOCK, BLOCK), dtype),
        )
    if name == "spmm_pairs":
        return (
            f((PAIRS, BLOCK, BLOCK), dtype),
            f((PAIRS, BLOCK, BLOCK), dtype),
        )
    if name == "dense_mm":
        return (
            f((DENSE_DIM, DENSE_DIM), dtype),
            f((DENSE_DIM, DENSE_DIM), dtype),
        )
    raise KeyError(name)


GRAPHS = {
    "spmm_block": spmm_block_graph,
    "spmm_pairs": spmm_pairs_graph,
    "dense_mm": dense_mm_graph,
}
