"""AOT compile path: lower every L2 graph to an HLO-text artifact.

Run ONCE by ``make artifacts``; Python never appears on the request path.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Lowering goes StableHLO -> XlaComputation with ``return_tuple=True``; the
Rust side unwraps with ``to_tuple1()``.

Alongside the ``.hlo.txt`` files we emit ``manifest.json`` recording the
dispatch geometry (BLOCK/PAIRS/SLOTS/DENSE_DIM) and per-artifact operand
shapes.  ``rust/src/runtime/artifact.rs`` parses and asserts against it, so
the planner and the artifacts cannot drift apart silently.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax

from . import model

try:  # jax moved the private xla_client around minor releases
    from jax._src.lib import xla_client as xc
except ImportError:  # pragma: no cover
    from jaxlib import xla_client as xc  # type: ignore


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_graph(name: str):
    fn = model.GRAPHS[name]
    args = model.example_args(name)
    return jax.jit(fn).lower(*args)


def shape_entry(s) -> dict:
    return {"shape": list(s.shape), "dtype": s.dtype.name}


def build(out_dir: str, names=None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    names = names or list(model.GRAPHS)
    manifest = {
        "block": model.BLOCK,
        "pairs": model.PAIRS,
        "slots": model.SLOTS,
        "dense_dim": model.DENSE_DIM,
        "artifacts": {},
    }
    for name in names:
        lowered = lower_graph(name)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [shape_entry(s) for s in model.example_args(name)],
            "hlo_bytes": len(text),
        }
        print(f"[aot] {name}: {len(text)} chars -> {path}", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of graph names")
    args = ap.parse_args()
    build(args.out_dir, args.only)


if __name__ == "__main__":
    main()
