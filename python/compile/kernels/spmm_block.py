"""L1 Pallas kernels: the MAC hot-spot of the synchronized SpMM mesh, re-thought
for the TPU MXU.

The paper's FPGA mesh pairs every MAC node with an index comparator so only
useful (nonzero x nonzero) work reaches the multiplier.  The TPU analogue
(DESIGN.md `§Hardware-Adaptation`) is *block-sparse SpMM*: the comparator
mesh's job — locating useful computation — is done at 32x32-block granularity
by the Rust coordinator (mirroring the paper's R=32 round synchronization),
and the MAC mesh's job is done here as dense 32x32 tile matmuls on the MXU.

Two kernels:

``spmm_pairs``
    grid over P gathered tile pairs; step p computes ``a[p] @ b[p]``.
    Pure batched MXU work; accumulation happens downstream.

``spmm_block``
    the full block-sparse contraction: pairs arrive *sorted by output tile*
    (the coordinator guarantees this — it is the block-granular version of
    the paper's sorted index streams), the output BlockSpec routes step p to
    output slot ``seg[p]`` via scalar prefetch, and consecutive steps that
    revisit the same slot accumulate in VMEM.  HBM traffic is one load per
    input tile and one store per output tile — the Pallas expression of the
    paper's "share operands along a row/column of the mesh".

Both kernels MUST be lowered with ``interpret=True``: real-TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot execute.  Correctness is
pinned against ``ref.py`` by ``python/tests``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile geometry.  32 matches the paper's round size R=32: one round of
# the synchronized mesh consumes (up to) 32 index positions per stream, one
# grid step here consumes a 32-wide K slab.
BLOCK = 32
# Default dispatch geometry (must match rust/src/runtime/artifact.rs and the
# manifest emitted by aot.py).
PAIRS = 128
SLOTS = 64


def _pairs_kernel(a_ref, b_ref, o_ref):
    """One grid step: o[p] = a[p] @ b[p] (a single 32x32 MXU pass)."""
    o_ref[...] = jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=o_ref.dtype
    )[None]


def spmm_pairs(a, b, *, interpret=True):
    """Batched tile products: ``(P, bm, bk) x (P, bk, bn) -> (P, bm, bn)``.

    The caller (L2 graph or the Rust coordinator) owns accumulation.
    """
    p, bm, bk = a.shape
    pb, bk2, bn = b.shape
    assert p == pb and bk == bk2, (a.shape, b.shape)
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    return pl.pallas_call(
        _pairs_kernel,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, bk, bn), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, bm, bn), out_dtype),
        interpret=interpret,
    )(a, b)


def _block_kernel(seg_ref, a_ref, b_ref, o_ref):
    """One grid step of the block-sparse contraction.

    ``seg_ref`` is the scalar-prefetched output-slot id per pair.  The output
    BlockSpec already routed ``o_ref`` to slot ``seg[p]``; we zero it on first
    visit (slot boundary in the sorted pair list) and accumulate otherwise.
    """
    p = pl.program_id(0)
    is_first = jnp.logical_or(
        p == 0, seg_ref[p] != seg_ref[jnp.maximum(p, 1) - 1]
    )

    prod = jnp.dot(a_ref[0], b_ref[0], preferred_element_type=o_ref.dtype)

    @pl.when(is_first)
    def _init():
        o_ref[...] = prod[None]

    @pl.when(jnp.logical_not(is_first))
    def _acc():
        o_ref[...] += prod[None]


def spmm_block(seg, a, b, *, slots=SLOTS, interpret=True):
    """Block-sparse SpMM contraction over gathered tile pairs.

    Args:
      seg: int32[P], output slot per pair, **sorted ascending** (grouped is
        enough; sorted is what the coordinator produces).  Padding pairs must
        repeat the last real slot id with zero-valued tiles.
      a:   (P, bm, bk) multiplicand tiles.
      b:   (P, bk, bn) multiplier tiles.
      slots: number of output tile slots T.

    Returns:
      (T, bm, bn) accumulated output tiles.  Slots never named in ``seg``
      hold unspecified values — callers must only read slots they routed
      pairs to (the Rust planner tracks the visited set).
    """
    p, bm, bk = a.shape
    pb, bk2, bn = b.shape
    assert p == pb and bk == bk2, (a.shape, b.shape)
    assert seg.shape == (p,) and seg.dtype == jnp.int32, (seg.shape, seg.dtype)
    out_dtype = jnp.promote_types(a.dtype, b.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda i, seg: (i, 0, 0)),
            pl.BlockSpec((1, bk, bn), lambda i, seg: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda i, seg: (seg[i], 0, 0)),
    )
    return pl.pallas_call(
        _block_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, bm, bn), out_dtype),
        interpret=interpret,
    )(seg, a, b)


def _dense_mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k):
    """Tiled dense matmul step: accumulate one K-slab into the (i,j) tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=acc_ref.dtype
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def dense_mm(x, y, *, tile=64, interpret=True):
    """Dense tiled matmul — the numeric twin of the conventional systolic MM
    baseline (every K element processed, zeros included)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert m % tile == 0 and n % tile == 0 and k % tile == 0, (x.shape, y.shape, tile)
    n_k = k // tile
    out_dtype = jnp.promote_types(x.dtype, y.dtype)
    return pl.pallas_call(
        functools.partial(_dense_mm_kernel, n_k=n_k),
        grid=(m // tile, n // tile, n_k),
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((tile, tile), jnp.float32)],
        interpret=interpret,
    )(x, y)
