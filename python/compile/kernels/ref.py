"""Pure-jnp oracles for the Pallas kernels — the build-time correctness pin.

Every kernel in ``spmm_block.py`` has an exact (same reduction order not
required, allclose suffices) reference here; ``python/tests`` sweeps shapes,
dtypes, densities, and segment patterns against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_pairs_ref(a, b):
    """Batched tile products: einsum over the pair axis."""
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    return jnp.einsum(
        "pik,pkj->pij", a, b, preferred_element_type=out_dtype
    ).astype(out_dtype)


def spmm_block_ref(seg, a, b, *, slots):
    """Segment-sum of pair products into output slots.

    Unlike the kernel, unvisited slots here are exact zeros — tests compare
    only visited slots (matching the kernel's contract).
    """
    prods = spmm_pairs_ref(a, b)
    return jax.ops.segment_sum(prods, seg, num_segments=slots)


def dense_mm_ref(x, y):
    out_dtype = jnp.promote_types(x.dtype, y.dtype)
    return jnp.dot(x, y, preferred_element_type=out_dtype).astype(out_dtype)


def blocked_spmm_ref(a_dense, b_dense, block):
    """End-to-end oracle for the full block-sparse pipeline: plain matmul.

    The planner/gather/scatter plumbing (numpy in tests, Rust in production)
    must make kernel output equal this, modulo f32 accumulation order.
    """
    del block  # blocking must not change the product
    return dense_mm_ref(a_dense, b_dense)
