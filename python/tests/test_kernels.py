"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes, densities, and segment patterns; the fixed
cases pin the exact artifact geometry (BLOCK/PAIRS/SLOTS) used by the Rust
runtime.
"""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic sampled examples
    from _hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import spmm_block as k

RNG = np.random.default_rng(0xC0FFEE)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.dtype(jnp.bfloat16) else dict(rtol=1e-4, atol=1e-4)


def rand_tiles(p, bm, bk, dtype=np.float32, density=1.0, rng=RNG):
    x = rng.standard_normal((p, bm, bk)).astype(np.float32)
    if density < 1.0:
        x *= rng.random((p, bm, bk)) < density
    return jnp.asarray(x).astype(dtype)


# ---------------------------------------------------------------- spmm_pairs

class TestPairs:
    @pytest.mark.parametrize("p", [1, 2, 7, 128])
    def test_matches_ref_f32(self, p):
        a, b = rand_tiles(p, 32, 32), rand_tiles(p, 32, 32)
        np.testing.assert_allclose(
            np.asarray(k.spmm_pairs(a, b)),
            np.asarray(ref.spmm_pairs_ref(a, b)),
            **tol(np.float32),
        )

    @settings(max_examples=25, deadline=None)
    @given(
        p=st.integers(1, 16),
        bm=st.sampled_from([8, 16, 32]),
        bk=st.sampled_from([8, 16, 32]),
        bn=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shape_sweep(self, p, bm, bk, bn, seed):
        rng = np.random.default_rng(seed)
        a = rand_tiles(p, bm, bk, rng=rng)
        b = rand_tiles(p, bk, bn, rng=rng)
        out = k.spmm_pairs(a, b)
        assert out.shape == (p, bm, bn)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.spmm_pairs_ref(a, b)), **tol(np.float32)
        )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        a, b = rand_tiles(4, 32, 32, dtype), rand_tiles(4, 32, 32, dtype)
        out = k.spmm_pairs(a, b)
        assert out.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(ref.spmm_pairs_ref(a, b), np.float32),
            **tol(np.dtype(dtype)),
        )

    def test_zero_tiles_give_zero(self):
        a = jnp.zeros((3, 32, 32), jnp.float32)
        b = rand_tiles(3, 32, 32)
        assert not np.asarray(k.spmm_pairs(a, b)).any()


# ---------------------------------------------------------------- spmm_block

def sorted_segments(draw_list, slots):
    """Normalize arbitrary ints to a sorted, grouped segment vector."""
    seg = np.sort(np.asarray(draw_list, np.int64) % slots).astype(np.int32)
    return jnp.asarray(seg)


class TestBlock:
    def run_and_check(self, seg, a, b, slots):
        out = np.asarray(k.spmm_block(seg, a, b, slots=slots))
        want = np.asarray(ref.spmm_block_ref(seg, a, b, slots=slots))
        visited = np.unique(np.asarray(seg))
        np.testing.assert_allclose(out[visited], want[visited], **tol(a.dtype))

    def test_single_pair(self):
        a, b = rand_tiles(1, 32, 32), rand_tiles(1, 32, 32)
        self.run_and_check(jnp.asarray([0], jnp.int32), a, b, 4)

    def test_all_same_slot(self):
        a, b = rand_tiles(9, 32, 32), rand_tiles(9, 32, 32)
        self.run_and_check(jnp.asarray([3] * 9, jnp.int32), a, b, 8)

    def test_all_distinct_slots(self):
        a, b = rand_tiles(8, 32, 32), rand_tiles(8, 32, 32)
        self.run_and_check(jnp.arange(8, dtype=jnp.int32), a, b, 8)

    def test_artifact_geometry(self):
        """The exact (PAIRS, SLOTS, BLOCK) shape the Rust runtime dispatches."""
        p, slots = k.PAIRS, k.SLOTS
        seg = sorted_segments(RNG.integers(0, slots, p), slots)
        a, b = rand_tiles(p, k.BLOCK, k.BLOCK), rand_tiles(p, k.BLOCK, k.BLOCK)
        self.run_and_check(seg, a, b, slots)

    def test_padding_contract(self):
        """Zero tiles repeating the last slot leave results unchanged."""
        a, b = rand_tiles(4, 32, 32), rand_tiles(4, 32, 32)
        seg = jnp.asarray([0, 0, 2, 2], jnp.int32)
        base = np.asarray(k.spmm_block(seg, a, b, slots=4))
        ap = jnp.concatenate([a, jnp.zeros((3, 32, 32), jnp.float32)])
        bp = jnp.concatenate([b, jnp.zeros((3, 32, 32), jnp.float32)])
        segp = jnp.asarray([0, 0, 2, 2, 2, 2, 2], jnp.int32)
        padded = np.asarray(k.spmm_block(segp, ap, bp, slots=4))
        for s in (0, 2):
            np.testing.assert_allclose(padded[s], base[s], rtol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        p=st.integers(1, 24),
        slots=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
        density=st.sampled_from([0.1, 0.5, 1.0]),
    )
    def test_segment_sweep(self, p, slots, seed, density):
        rng = np.random.default_rng(seed)
        seg = sorted_segments(rng.integers(0, slots, p), slots)
        a = rand_tiles(p, 16, 16, density=density, rng=rng)
        b = rand_tiles(p, 16, 16, density=density, rng=rng)
        self.run_and_check(seg, a, b, slots)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        a = rand_tiles(6, 32, 32, dtype)
        b = rand_tiles(6, 32, 32, dtype)
        seg = jnp.asarray([0, 0, 0, 1, 1, 3], jnp.int32)
        self.run_and_check(seg, a, b, 4)

    def test_rejects_bad_seg_dtype(self):
        # (int64 silently truncates to int32 on CPU jax, so use float32 here)
        a, b = rand_tiles(2, 32, 32), rand_tiles(2, 32, 32)
        with pytest.raises(AssertionError):
            k.spmm_block(jnp.asarray([0.0, 1.0], jnp.float32), a, b, slots=2)


# ------------------------------------------------------------------ dense_mm

class TestDense:
    @pytest.mark.parametrize("m,kk,n", [(64, 64, 64), (128, 256, 64), (256, 256, 256)])
    def test_matches_ref(self, m, kk, n):
        rng = np.random.default_rng(m * 7 + n)
        x = jnp.asarray(rng.standard_normal((m, kk)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((kk, n)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(k.dense_mm(x, y, tile=64)),
            np.asarray(ref.dense_mm_ref(x, y)),
            rtol=1e-4, atol=1e-4,
        )

    def test_rejects_unaligned(self):
        x = jnp.zeros((65, 64), jnp.float32)
        y = jnp.zeros((64, 64), jnp.float32)
        with pytest.raises(AssertionError):
            k.dense_mm(x, y, tile=64)

    @settings(max_examples=10, deadline=None)
    @given(
        mt=st.integers(1, 3), ktt=st.integers(1, 4), nt=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_tile_sweep(self, mt, ktt, nt, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((mt * 64, ktt * 64)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((ktt * 64, nt * 64)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(k.dense_mm(x, y, tile=64)),
            np.asarray(x) @ np.asarray(y),
            rtol=1e-3, atol=1e-3,
        )
