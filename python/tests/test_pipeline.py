"""End-to-end L2/L1 pipeline: sparse matrices -> planner -> kernel -> dense C.

This replays, in numpy, exactly what the Rust coordinator does per SpMM job
(blocking, block-pair matching, dispatch chunking, scatter) and checks the
final product against a plain matmul oracle.
"""

import numpy as np
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback: deterministic sampled examples
    from _hypothesis import given, settings, strategies as st

from compile import blocking
from compile.kernels import ref
from compile.kernels import spmm_block as k


def rand_sparse(m, n, density, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, n)).astype(np.float32)
    x *= rng.random((m, n)) < density
    return x


def run_pipeline(a, b, *, block=16, pairs=8, slots=4, use_block_kernel=True):
    dispatches = blocking.plan(a, b, block=block, pairs=pairs, slots=slots)

    def exec_dispatch(d):
        if use_block_kernel:
            return k.spmm_block(
                jnp.asarray(d.seg), jnp.asarray(d.a), jnp.asarray(d.b),
                slots=slots,
            )
        # fallback path: products + host-side segment accumulation
        prods = np.asarray(k.spmm_pairs(jnp.asarray(d.a), jnp.asarray(d.b)))
        out = np.zeros((slots,) + prods.shape[1:], np.float32)
        for s, p in zip(d.seg[: d.n_real], prods[: d.n_real]):
            out[s] += p
        return out

    return blocking.scatter(
        dispatches, exec_dispatch, a.shape[0], b.shape[1], block=block
    )


class TestPipeline:
    def test_tiny_exact(self):
        a = np.array([[1.0, 0.0], [0.0, 2.0]], np.float32)
        b = np.array([[3.0, 0.0], [0.0, 4.0]], np.float32)
        got = run_pipeline(a, b, block=2, pairs=2, slots=2)
        np.testing.assert_allclose(got, a @ b, rtol=1e-6)

    def test_identity(self):
        a = np.eye(32, dtype=np.float32)
        b = rand_sparse(32, 32, 0.3, 1)
        got = run_pipeline(a, b, block=8, pairs=4, slots=4)
        np.testing.assert_allclose(got, b, rtol=1e-5, atol=1e-5)

    def test_empty_product(self):
        """Structurally disjoint A/B blocks -> zero C, zero dispatches."""
        a = np.zeros((32, 32), np.float32)
        a[:16, :16] = 1.0
        b = np.zeros((32, 32), np.float32)
        b[16:, 16:] = 1.0
        dispatches = blocking.plan(a, b, block=16, pairs=8, slots=4)
        assert dispatches == []
        got = run_pipeline(a, b, block=16)
        np.testing.assert_allclose(got, np.zeros((32, 32)))

    def test_unaligned_dims(self):
        a = rand_sparse(33, 47, 0.2, 2)
        b = rand_sparse(47, 29, 0.2, 3)
        got = run_pipeline(a, b, block=16, pairs=8, slots=4)
        np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)

    def test_both_kernel_paths_agree(self):
        a = rand_sparse(64, 64, 0.15, 4)
        b = rand_sparse(64, 64, 0.15, 5)
        via_block = run_pipeline(a, b, use_block_kernel=True)
        via_pairs = run_pipeline(a, b, use_block_kernel=False)
        np.testing.assert_allclose(via_block, via_pairs, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(via_block, a @ b, rtol=1e-4, atol=1e-4)

    def test_slot_overflow_splits_dispatches(self):
        """More output tiles than SLOTS forces multiple dispatches."""
        a = np.eye(64, dtype=np.float32)  # 8x8 diag blocks at block=8
        b = rand_sparse(64, 64, 0.9, 6)
        dispatches = blocking.plan(a, b, block=8, pairs=64, slots=4)
        assert len(dispatches) >= 2
        got = run_pipeline(a, b, block=8, pairs=64, slots=4)
        np.testing.assert_allclose(got, b, rtol=1e-4, atol=1e-4)

    def test_group_split_across_dispatches_accumulates(self):
        """One output tile with more pairs than P: partials must add up."""
        a = rand_sparse(8, 64, 0.9, 7)  # 1x8 blocks at block=8 -> 8 pairs, 1 out tile
        b = rand_sparse(64, 8, 0.9, 8)
        dispatches = blocking.plan(a, b, block=8, pairs=3, slots=4)
        assert len(dispatches) >= 3
        got = run_pipeline(a, b, block=8, pairs=3, slots=4)
        np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(8, 48), kk=st.integers(8, 48), n=st.integers(8, 48),
        da=st.sampled_from([0.02, 0.1, 0.4]),
        db=st.sampled_from([0.02, 0.1, 0.4]),
        block=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_random_sweep(self, m, kk, n, da, db, block, seed):
        a = rand_sparse(m, kk, da, seed)
        b = rand_sparse(kk, n, db, seed + 1)
        got = run_pipeline(a, b, block=block, pairs=8, slots=4)
        np.testing.assert_allclose(
            got, ref.blocked_spmm_ref(a, b, block), rtol=1e-4, atol=1e-4
        )
