import os
import sys

# Make `compile` importable whether pytest runs from python/ or the repo
# root, and the tests dir itself for the offline `_hypothesis` fallback.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PY_ROOT = os.path.dirname(_HERE)
for _p in (_PY_ROOT, _HERE):
    if _p not in sys.path:
        sys.path.insert(0, _p)
