"""Minimal offline stand-in for the `hypothesis` API surface these tests use.

The real hypothesis is preferred (and used automatically when installed —
see the try/except in the test modules); this fallback keeps the property
tests *running* in offline environments instead of erroring at collection.
It implements just `given`, `settings`, and the `integers` / `sampled_from`
strategies, drawing a deterministic sample per example from a seeded
numpy Generator so failures are reproducible.
"""

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801 - mimics `hypothesis.strategies` module name
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value))
        )

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))


# alias matching `from hypothesis import strategies as st`
st = strategies


class settings:  # noqa: N801 - mimics the hypothesis decorator
    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, f):
        f._fallback_settings = self
        return f


def given(**strategy_kwargs):
    def deco(f):
        # NOTE: no functools.wraps — pytest must see the wrapper's bare
        # (*args, **kwargs) signature, not the strategy parameters of the
        # wrapped property (it would treat them as missing fixtures).
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", None)
            n = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            # deterministic per-test stream: seeded by the test's name, so
            # failures replay exactly
            seed = sum(ord(c) for c in f.__qualname__) * 2654435761 % (2**32)
            rng = np.random.default_rng(seed)
            for case in range(n):
                drawn = {k: s.example(rng) for k, s in strategy_kwargs.items()}
                try:
                    f(*args, **kwargs, **drawn)
                except Exception:
                    print(
                        f"falsifying example (case {case}): "
                        f"{f.__qualname__}({drawn})"
                    )
                    raise

        wrapper.__name__ = f.__name__
        wrapper.__qualname__ = f.__qualname__
        wrapper.__doc__ = f.__doc__
        wrapper._fallback_given = True
        return wrapper

    return deco


def _self_test():
    calls = []

    @settings(max_examples=7)
    @given(x=st.integers(0, 5), tag=st.sampled_from(["a", "b"]))
    def prop(x, tag):
        assert 0 <= x <= 5 and tag in ("a", "b")
        calls.append((x, tag))

    prop()
    assert len(calls) == 7, calls


if __name__ == "__main__":
    _self_test()
    print("fallback hypothesis shim OK")
