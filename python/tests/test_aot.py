"""AOT path: every graph lowers to parseable HLO text + a consistent manifest."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out)
    return out, manifest


class TestAot:
    def test_all_graphs_emitted(self, built):
        out, manifest = built
        assert set(manifest["artifacts"]) == set(model.GRAPHS)
        for name, entry in manifest["artifacts"].items():
            path = os.path.join(out, entry["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert text.startswith("HloModule"), name
            # return_tuple=True: root must be a tuple for rust's to_tuple1()
            assert "tuple(" in text, name

    def test_manifest_geometry(self, built):
        _, manifest = built
        assert manifest["block"] == model.BLOCK == 32
        assert manifest["pairs"] == model.PAIRS == 128
        assert manifest["slots"] == model.SLOTS == 64
        assert manifest["dense_dim"] == model.DENSE_DIM == 256

    def test_manifest_shapes_match_example_args(self, built):
        _, manifest = built
        for name, entry in manifest["artifacts"].items():
            args = model.example_args(name)
            assert len(entry["args"]) == len(args)
            for got, want in zip(entry["args"], args):
                assert tuple(got["shape"]) == want.shape
                assert got["dtype"] == want.dtype.name

    def test_manifest_json_roundtrip(self, built):
        out, manifest = built
        on_disk = json.load(open(os.path.join(out, "manifest.json")))
        assert on_disk == json.loads(json.dumps(manifest))

    def test_idempotent_rebuild(self, built):
        """`make artifacts` reruns must produce byte-identical HLO."""
        out, manifest = built
        name = "spmm_pairs"
        first = open(os.path.join(out, manifest["artifacts"][name]["file"])).read()
        again = aot.to_hlo_text(aot.lower_graph(name))
        assert first == again

    def test_lowered_graph_still_executes(self):
        """The jitted (pre-lowering) graph computes the right numbers."""
        rng = np.random.default_rng(11)
        seg = jnp.asarray(
            np.sort(rng.integers(0, model.SLOTS, model.PAIRS)).astype(np.int32)
        )
        a = jnp.asarray(
            rng.standard_normal((model.PAIRS, model.BLOCK, model.BLOCK)),
            jnp.float32,
        )
        b = jnp.asarray(
            rng.standard_normal((model.PAIRS, model.BLOCK, model.BLOCK)),
            jnp.float32,
        )
        (out,) = jax.jit(model.spmm_block_graph)(seg, a, b)
        want = jax.ops.segment_sum(
            jnp.einsum("pik,pkj->pij", a, b), seg, num_segments=model.SLOTS
        )
        visited = np.unique(np.asarray(seg))
        np.testing.assert_allclose(
            np.asarray(out)[visited], np.asarray(want)[visited],
            rtol=1e-4, atol=1e-4,
        )
